//! Autoregressive decode engine: a full decoder-only transformer forward
//! pass, token by token with a growing KV cache, whose *parameterized*
//! matmuls run on the emulated crossbar chip ([`FunctionalChip`]) under
//! any of the three mapping strategies — the workload the paper actually
//! measures (Fig. 7/8's token-streaming decode regime), not an isolated
//! matvec.
//!
//! Split of responsibilities (paper Fig. 2b):
//! * **Para ops** (`wq/wk/wv/wo/ffn1/ffn2`) — weight-stationary in CIM
//!   arrays; executed by `FunctionalChip::run_op_into` replaying the
//!   compiled plan (`scheduler::plan`) with scheduler-issued
//!   row-activation masks, pre-rotated column conversion and stride
//!   permutations.
//! * **NonPara ops** (attention scores `qk` and context `av`) — digital,
//!   on the MHA unit: computed here in f32 against the KV cache; their
//!   cost is `trace::mha_token_cost` (grows with the cache).
//! * Everything else (LayerNorm, GeLU, residuals, embedding/LM head) —
//!   DPU vector ops, identical across backends.
//!
//! The steady-state token loop is allocation-free: the engine owns one
//! [`EngineBufs`] set of activation buffers (reused every token, every
//! request), the chip owns its pass scratch, and the only per-token heap
//! traffic is the KV-cache append — state, not scratch.
//!
//! Because the chip's Monarch passes replay the factored reference's f32
//! operations in the same order, SparseMap/DenseMap decode is
//! bit-identical to the [`RectMonarch`] reference model; Linear programs
//! the *dense materialization* of the same operator and agrees to float
//! tolerance — so greedy token sequences match across all three
//! strategies (tier-1 `tests/integration_decode.rs`).
//!
//! [`BatchDecodeEngine`] extends the same loop to a slot pool: B
//! sequences share one programmed chip, every Para op replays its pass
//! tables once per step for the whole batch
//! (`FunctionalChip::run_op_batch_into`, stride-B interleaved lanes),
//! and slots admit/evict between steps (continuous batching). Each lane
//! is bit-identical to the single-stream path, so batched logits never
//! depend on batchmates (`tests/prop_batch_decode.rs`).
//!
//! Since PR 4 the batched engine steps *chunks*, not single tokens:
//! [`BatchDecodeEngine::step_chunks`] advances each slot by a
//! variable-length token chunk through one batched replay with **lanes =
//! positions** (`sim::prefill`, DESIGN.md §6c) — decode lanes are chunks
//! of 1, prompt ingestion rides C positions per replay, bit-identical to
//! token-by-token feeding (`tests/prop_prefill.rs`). Requests whose
//! prompt + generation exceed the context window are rejected with a
//! clear error at admission instead of silently clamping the position.

use std::collections::HashMap;

use crate::cim::{AnalogMode, CimParams, Cost};
use crate::mapping::Strategy;
use crate::model::{para_ops, MatmulOp, ModelConfig};
use crate::monarch::{MonarchMatrix, RectMonarch};
use crate::sim::exec::FunctionalChip;
use crate::sim::prefill::{self, allocate_chunks, ChunkWorkspace, KvCache};
use crate::sim::shard::{sharded_chunk_step, PipelineStats, ShardedBackend};
use crate::sim::trace::{decode_token_cost, DecodeTrace};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Parameterized-op indices of one decoder layer (into the para-op list).
#[derive(Clone, Copy, Debug)]
pub(crate) struct LayerOps {
    pub(crate) wq: usize,
    pub(crate) wk: usize,
    pub(crate) wv: usize,
    pub(crate) wo: usize,
    pub(crate) ffn1: usize,
    pub(crate) ffn2: usize,
}

/// A synthetic Monarch decoder-only transformer: every Para weight is a
/// tile grid of Monarch factors (deterministically seeded), plus token
/// embeddings, learned positional embeddings and an untied LM head (a
/// tied head makes a random-weight model echo its input token forever —
/// untied gives non-degenerate greedy sequences, with comfortable
/// argmax margins, ~0.01 at the tiny config).
pub struct DecodeModel {
    pub cfg: ModelConfig,
    pub ops: Vec<MatmulOp>,
    pub weights: Vec<RectMonarch>,
    /// Token embedding table (vocab x d).
    pub embedding: Matrix,
    /// Learned positional embeddings (seq x d).
    pub positional: Matrix,
    /// Untied LM head (vocab x d).
    pub lm_head: Matrix,
    pub(crate) layers: Vec<LayerOps>,
}

/// Variance-preserving random Monarch tile (factors scaled by 1/sqrt(b)).
fn scaled_monarch(b: usize, rng: &mut Pcg32) -> MonarchMatrix {
    let mut m = MonarchMatrix::randn(b, rng);
    let s = 1.0 / (b as f32).sqrt();
    for v in m.l.data.iter_mut() {
        *v *= s;
    }
    for v in m.r.data.iter_mut() {
        *v *= s;
    }
    m
}

impl DecodeModel {
    /// Deterministically synthesize weights for a decoder-only config.
    /// Takes the config by value — callers that keep one pass a clone,
    /// everyone else just moves it in.
    pub fn synth(cfg: ModelConfig, seed: u64) -> DecodeModel {
        assert_eq!(
            cfg.enc_layers, 0,
            "decode engine targets decoder-only models (got {})",
            cfg.name
        );
        assert!(cfg.dec_layers > 0, "model has no decoder layers");
        let d = cfg.d_model;
        let b = cfg.monarch_b();
        let ops = para_ops(&cfg);
        let weights: Vec<RectMonarch> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                let mut rng = Pcg32::stream(seed, i as u64);
                let tiles = op.rows.div_ceil(d) * op.cols.div_ceil(d);
                RectMonarch {
                    rows: op.rows,
                    cols: op.cols,
                    n: d,
                    tiles: (0..tiles).map(|_| scaled_monarch(b, &mut rng)).collect(),
                }
            })
            .collect();
        let by_name: HashMap<&str, usize> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| (op.name.as_str(), i))
            .collect();
        let layers = (0..cfg.dec_layers)
            .map(|l| {
                let idx = |w: &str| -> usize {
                    *by_name
                        .get(format!("dec{l}.{w}").as_str())
                        .unwrap_or_else(|| panic!("missing op dec{l}.{w}"))
                };
                LayerOps {
                    wq: idx("wq"),
                    wk: idx("wk"),
                    wv: idx("wv"),
                    wo: idx("wo"),
                    ffn1: idx("ffn1"),
                    ffn2: idx("ffn2"),
                }
            })
            .collect();
        let embedding = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0x5eed));
        let positional =
            Matrix::randn(cfg.seq, d, &mut Pcg32::stream(seed, 0x905e)).scale(0.1);
        let lm_head = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0xeadd));
        DecodeModel {
            cfg,
            ops,
            weights,
            embedding,
            positional,
            lm_head,
            layers,
        }
    }

    /// Reference Para matmul (`y = W x`) through the factored tiles.
    pub fn reference_matvec(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        self.weights[op_idx].matvec(x)
    }
}

/// Where the Para matmuls execute.
pub enum ParaBackend {
    /// Plain `RectMonarch::matvec` — the golden model.
    Reference,
    /// Emulated crossbar chip programmed under one mapping strategy.
    Chip(Box<FunctionalChip>),
}

impl ParaBackend {
    /// Execute `y = W x` for op `op_idx` into a caller buffer. The chip
    /// path replays the compiled plan allocation-free; the reference
    /// path runs the golden factored matvec.
    fn run_into(&mut self, model: &DecodeModel, op_idx: usize, x: &[f32], y: &mut [f32]) {
        match self {
            ParaBackend::Reference => {
                let r = model.reference_matvec(op_idx, x);
                y.copy_from_slice(&r);
            }
            ParaBackend::Chip(chip) => chip.run_op_into(op_idx, x, y),
        }
    }

    /// Batched form: `batch` stride-B interleaved input vectors through
    /// one plan replay (`xs[c * batch + l]` is lane `l`'s element `c`).
    /// The chip path amortizes every analog pass over the batch; the
    /// reference path runs the golden matvec lane by lane. Either way,
    /// lane `l` is bit-identical to a `run_into` call over lane `l`'s
    /// vector — the invariant batched decode *and* chunked prefill
    /// (lanes = positions) rest on.
    pub(crate) fn run_batch_into(
        &mut self,
        model: &DecodeModel,
        op_idx: usize,
        batch: usize,
        xs: &[f32],
        ys: &mut [f32],
    ) {
        match self {
            ParaBackend::Reference => {
                let cols = model.ops[op_idx].cols;
                let mut x = vec![0.0f32; cols];
                for l in 0..batch {
                    for (c, xv) in x.iter_mut().enumerate() {
                        *xv = xs[c * batch + l];
                    }
                    let r = model.reference_matvec(op_idx, &x);
                    for (i, v) in r.iter().enumerate() {
                        ys[i * batch + l] = *v;
                    }
                }
            }
            ParaBackend::Chip(chip) => chip.run_op_batch_into(op_idx, batch, xs, ys),
        }
    }
}

/// How the batched engine executes a step: every layer on one backend
/// (the mono path every PR so far used), or layer ranges sharded across
/// N stage chips driven as a pipeline (`sim::shard`, DESIGN.md §6f).
pub(crate) enum EngineBackend {
    Mono(ParaBackend),
    Sharded(ShardedBackend),
}

/// Per-token activation buffers, allocated once per engine and reused
/// across tokens and requests (the serving worker keeps one engine, so
/// this scratch also persists across requests).
struct EngineBufs {
    /// Residual stream (d).
    h: Vec<f32>,
    /// LayerNorm output feeding the current sub-block (d).
    x: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context (d).
    ctx: Vec<f32>,
    o: Vec<f32>,
    /// FFN hidden (d_ff).
    f: Vec<f32>,
    g: Vec<f32>,
    /// Final LayerNorm output (d).
    hn: Vec<f32>,
    /// Attention score scratch (grows to the KV length; capacity
    /// reserved for the model's context window).
    scores: Vec<f32>,
    /// LM-head logits of the latest forwarded position (vocab).
    logits: Vec<f32>,
}

impl EngineBufs {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        Self {
            h: vec![0.0; d],
            x: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            o: vec![0.0; d],
            f: vec![0.0; cfg.d_ff],
            g: vec![0.0; d],
            hn: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            logits: vec![0.0; cfg.vocab],
        }
    }
}

/// The decode engine: owns the model, the Para backend, the KV cache and
/// the per-token scratch; generates tokens greedily and accounts
/// latency/energy per token.
pub struct DecodeEngine {
    pub model: DecodeModel,
    backend: ParaBackend,
    params: CimParams,
    /// Per-layer key/value cache (one d-vector per cached position).
    kv: KvCache,
    pub trace: DecodeTrace,
    bufs: EngineBufs,
}

/// Result of one greedy generation run. The per-token costs are *moved*
/// out of the engine's trace (no deep copy): after `generate` returns,
/// the engine's own trace is empty until the next run records into it.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// The generated token ids (prompt excluded).
    pub tokens: Vec<i32>,
    /// Modeled cost of every processed position (prompt + generated).
    pub per_token: Vec<Cost>,
}

impl DecodeResult {
    /// Summed modeled cost of the whole run (the counterpart of
    /// `DecodeTrace::total` for the moved-out per-token records).
    pub fn total(&self) -> Cost {
        crate::sim::trace::sum_costs(&self.per_token)
    }
}

pub(crate) fn layer_norm_into(x: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (o, v) in out.iter_mut().zip(x) {
        *o = (v - mean) * inv;
    }
}

pub(crate) fn gelu(x: &mut [f32]) {
    // tanh approximation (identical across backends; DPU op)
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044_715 * u * u * u)).tanh());
    }
}

pub(crate) fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

/// Context-window admission check shared by every ingestion path: a
/// request of `prompt` positions that will generate `n_tokens` more must
/// fit the model's `seq` positional embeddings. Violations are caller
/// bugs (or unvalidated client input) and fail loudly — the engine never
/// silently reuses the last position (ISSUE 4 regression).
pub(crate) fn assert_fits_context(cfg: &ModelConfig, prompt: usize, n_tokens: usize) {
    assert!(
        prompt + n_tokens <= cfg.seq,
        "request exceeds the context window: prompt {prompt} + {n_tokens} generated \
         tokens > seq {} — reject at admission/validation time",
        cfg.seq
    );
}

impl DecodeEngine {
    /// Engine with the golden (non-CIM) Para backend.
    pub fn reference(model: DecodeModel) -> DecodeEngine {
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            kv: KvCache::new(layers),
            model,
            backend: ParaBackend::Reference,
            params: CimParams::default(),
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// Engine whose Para ops run on an emulated chip programmed with the
    /// given mapping strategy. Takes the CIM parameters by value (the
    /// engine stores them for per-token cost accounting).
    pub fn on_chip(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
    ) -> DecodeEngine {
        Self::on_chip_analog(model, params, strategy, None)
    }

    /// [`DecodeEngine::on_chip`] with opt-in analog realism: the chip is
    /// programmed under `analog` (seeded PCM corruption + replay-time
    /// ADC cap, DESIGN.md §6i). `None` — and `Some(&AnalogMode::ideal())`,
    /// by construction — decode bit-identically to the exact path.
    pub fn on_chip_analog(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        analog: Option<&AnalogMode>,
    ) -> DecodeEngine {
        let chip = FunctionalChip::program_rect_analog(
            &model.cfg,
            &model.ops,
            &model.weights,
            &params,
            strategy,
            analog,
        );
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            kv: KvCache::new(layers),
            model,
            backend: ParaBackend::Chip(Box::new(chip)),
            params,
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// The chip's mapping (None for the reference backend).
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        match &self.backend {
            ParaBackend::Chip(c) => Some(&c.mapping),
            ParaBackend::Reference => None,
        }
    }

    /// The chip's analog mode (None on the reference backend or when
    /// programmed without one).
    pub fn analog_mode(&self) -> Option<&AnalogMode> {
        match &self.backend {
            ParaBackend::Chip(c) => c.analog_mode(),
            ParaBackend::Reference => None,
        }
    }

    /// Select the chip's pass-table replay encoding (no-op on the
    /// reference backend). Bit-identical either way; used by the bench
    /// to compare bit-block replay against the index-list baseline.
    pub fn set_replay_mode(&mut self, mode: crate::sim::exec::ReplayMode) {
        if let ParaBackend::Chip(chip) = &mut self.backend {
            chip.set_replay_mode(mode);
        }
    }

    /// Clear the KV cache, the trace and the stale per-request scratch
    /// (new sequence). After `reset` the engine is observationally
    /// indistinguishable from a freshly constructed one: the attention
    /// score window and the previous request's logits are wiped too, so
    /// a caller that reads logits before the first `forward` of the new
    /// request can never see the old request's distribution.
    pub fn reset(&mut self) {
        clear_request_state(
            &mut self.kv,
            &mut self.trace,
            &mut self.bufs.scores,
            &mut self.bufs.logits,
        );
    }

    /// Cached positions so far.
    pub fn kv_len(&self) -> usize {
        self.kv.len()
    }

    /// The engine's key/value cache (read-only — for cross-checking
    /// chunked prefill against token-by-token ingestion).
    pub fn kv_cache(&self) -> &KvCache {
        &self.kv
    }

    /// LM-head logits of the latest forwarded position (borrowed from
    /// the engine's reusable logit buffer, like
    /// [`DecodeEngine::forward`]'s return — all zeros before the first
    /// forward of a request).
    pub fn logits(&self) -> &[f32] {
        &self.bufs.logits
    }

    /// Roll the KV cache back to `len` positions (speculative-decoding
    /// rejection, `sim::speculate`). Only *state* is rolled back: the
    /// cost trace keeps its records, because the dropped positions
    /// already drove rows and converted columns — rejected work stays
    /// on the bill (DESIGN.md §6d).
    pub fn truncate_kv(&mut self, len: usize) {
        self.kv.truncate(len);
    }

    /// Process one token at the next position; returns the LM-head
    /// logits (borrowed from the engine's reusable logit buffer — copy
    /// them out if they must outlive the next forward). Appends K/V to
    /// the cache and records the position's cost.
    ///
    /// Panics if the cache already spans the whole context window —
    /// callers must validate request length at admission
    /// ([`DecodeEngine::generate`] and the serving layer do).
    pub fn forward(&mut self, token: i32) -> &[f32] {
        let pos = self.kv_len();
        assert_fits_context(&self.model.cfg, pos, 1);
        let DecodeEngine {
            model,
            backend,
            params,
            kv,
            trace,
            bufs,
        } = self;
        let d = model.cfg.d_model;
        let heads = model.cfg.n_heads;
        let dh = model.cfg.d_head();
        let vocab = model.cfg.vocab;
        let n_layers = model.cfg.dec_layers;
        let tok = (token.max(0) as usize).min(vocab - 1);

        for ((hv, e), p) in bufs
            .h
            .iter_mut()
            .zip(model.embedding.row(tok))
            .zip(model.positional.row(pos))
        {
            *hv = e + p;
        }

        for l in 0..n_layers {
            let ops = model.layers[l];
            // --- self-attention sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.wq, &bufs.x, &mut bufs.q);
            backend.run_into(model, ops.wk, &bufs.x, &mut bufs.k);
            backend.run_into(model, ops.wv, &bufs.x, &mut bufs.v);
            kv.push(l, bufs.k.clone(), bufs.v.clone());
            attend_into(
                &bufs.q,
                &kv.keys[l],
                &kv.values[l],
                heads,
                dh,
                &mut bufs.scores,
                &mut bufs.ctx,
            );
            backend.run_into(model, ops.wo, &bufs.ctx, &mut bufs.o);
            for (hv, ov) in bufs.h.iter_mut().zip(&bufs.o) {
                *hv += ov;
            }
            // --- feed-forward sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.ffn1, &bufs.x, &mut bufs.f);
            gelu(&mut bufs.f);
            backend.run_into(model, ops.ffn2, &bufs.f, &mut bufs.g);
            for (hv, gv) in bufs.h.iter_mut().zip(&bufs.g) {
                *hv += gv;
            }
        }

        // untied LM head over the final LayerNorm
        layer_norm_into(&bufs.h, &mut bufs.hn);
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for (t, lv) in bufs.logits.iter_mut().enumerate() {
            let row = model.lm_head.row(t);
            let mut acc = 0.0f32;
            for (r, x) in row.iter().zip(&bufs.hn) {
                acc += r * x;
            }
            *lv = acc * inv_sqrt_d;
        }

        // cost accounting: the mapped Para path + cache-sized MHA work
        let kv_len = kv.len();
        let cost = match backend {
            ParaBackend::Chip(chip) => {
                decode_token_cost(&model.cfg, &chip.mapping, params, kv_len)
            }
            ParaBackend::Reference => Cost::default(),
        };
        trace.record(cost);
        &bufs.logits[..]
    }

    /// Greedy autoregressive generation: feed `prompt`, then emit
    /// `n_tokens` argmax continuations. The engine is reset first.
    /// Requests that cannot fit the context window (`prompt.len() +
    /// n_tokens > seq`) are rejected with a clear panic — validate at
    /// admission.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> DecodeResult {
        assert!(!prompt.is_empty(), "need at least one prompt token");
        assert_fits_context(&self.model.cfg, prompt.len(), n_tokens);
        self.reset();
        for &t in prompt {
            self.forward(t);
        }
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let next = argmax(&self.bufs.logits) as i32;
            tokens.push(next);
            self.forward(next);
        }
        DecodeResult {
            tokens,
            per_token: std::mem::take(&mut self.trace.per_token),
        }
    }

    /// Teacher-forced scoring: per-position logits (`seq * vocab`) for a
    /// full token window, plus the summed modeled cost — the CIM-sim
    /// serving contract (`coordinator::server::Backend::CimSim`).
    pub fn score(&mut self, tokens: &[i32]) -> (Vec<f32>, Cost) {
        assert_fits_context(&self.model.cfg, tokens.len(), 0);
        self.reset();
        let vocab = self.model.cfg.vocab;
        let mut out = Vec::with_capacity(tokens.len() * vocab);
        for &t in tokens {
            let logits = self.forward(t);
            out.extend_from_slice(logits);
        }
        (out, self.trace.total())
    }
}

/// One sequence slot of the batched engine: its own KV cache, logits,
/// attention-score scratch and per-position cost trace — everything
/// request-private, so slots at different positions (ragged lengths)
/// coexist in one batch. Activation scratch is *not* per-slot: the
/// chunked step stages all lanes through the engine's shared
/// [`ChunkWorkspace`].
pub(crate) struct BatchSlot {
    /// Occupied by an in-flight sequence.
    pub(crate) active: bool,
    pub(crate) kv: KvCache,
    pub(crate) trace: DecodeTrace,
    /// LM-head logits of the slot's latest stepped position.
    pub(crate) logits: Vec<f32>,
    /// Attention score scratch (grows to the KV length).
    pub(crate) scores: Vec<f32>,
}

impl BatchSlot {
    fn new(cfg: &ModelConfig) -> Self {
        Self {
            active: false,
            kv: KvCache::new(cfg.dec_layers),
            trace: DecodeTrace::new(),
            logits: vec![0.0; cfg.vocab],
            scores: Vec::with_capacity(cfg.seq),
        }
    }

    fn kv_len(&self) -> usize {
        self.kv.len()
    }

    /// Wipe all request state so the next occupant starts from a
    /// provably clean slot — the same wipe [`DecodeEngine::reset`]
    /// performs, through the same helper.
    fn clear(&mut self) {
        clear_request_state(
            &mut self.kv,
            &mut self.trace,
            &mut self.scores,
            &mut self.logits,
        );
    }
}

/// Wipe one request's state — KV cache, cost trace, attention score
/// window and logits. Single definition of "request state", shared by
/// [`DecodeEngine::reset`] and `BatchSlot::clear` so the two reuse paths
/// can never drift apart on what gets cleared.
fn clear_request_state(
    kv: &mut KvCache,
    trace: &mut DecodeTrace,
    scores: &mut Vec<f32>,
    logits: &mut [f32],
) {
    kv.clear();
    trace.clear();
    scores.clear();
    logits.fill(0.0);
}

/// Batched decode engine: a fixed set of sequence slots sharing ONE
/// programmed chip. Each [`BatchDecodeEngine::step_chunks`] advances any
/// subset of the slots by a token *chunk* (decode continuations are
/// chunks of 1; prompt ingestion brings C positions — chunked prefill),
/// replaying every Para op's compiled pass tables once for all lanes
/// (`FunctionalChip::run_op_batch_into`, lanes = Σ chunk lengths) — the
/// weight-stationary amortization that turns the memory-bound decode
/// stage into a throughput-oriented serving core. Slots are
/// request-private (own KV cache, logits and trace), may sit at
/// different positions (ragged lengths), and can be admitted/evicted
/// between steps without touching in-flight neighbours (continuous
/// batching, `coordinator::server`).
///
/// Because every lane of the batched replay is bit-identical to the
/// single-stream path, a slot's logits never depend on its batchmates or
/// its chunking: any interleaving of admissions/evictions/chunk sizes
/// produces exactly the tokens of independent [`DecodeEngine`]s
/// (`tests/prop_batch_decode.rs`, `tests/prop_prefill.rs`).
pub struct BatchDecodeEngine {
    pub model: DecodeModel,
    backend: EngineBackend,
    params: CimParams,
    slots: Vec<BatchSlot>,
    /// Shared lane-major activation workspace of the chunked step —
    /// allocated once, grown to the widest step, reused forever.
    ws: ChunkWorkspace,
    /// Pipeline observability, fed by sharded steps (stays default/empty
    /// on the mono path).
    pipeline: PipelineStats,
}

impl BatchDecodeEngine {
    /// Batched engine with the golden (non-CIM) Para backend.
    pub fn reference(model: DecodeModel, capacity: usize) -> BatchDecodeEngine {
        Self::with_backend(model, ParaBackend::Reference, CimParams::default(), capacity)
    }

    /// Batched engine whose Para ops run on an emulated chip programmed
    /// with the given mapping strategy (one chip for all slots — the
    /// weights are resident once, the batch rides for free).
    pub fn on_chip(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        capacity: usize,
    ) -> BatchDecodeEngine {
        Self::on_chip_analog(model, params, strategy, capacity, None)
    }

    /// [`BatchDecodeEngine::on_chip`] with opt-in analog realism (seeded
    /// PCM corruption + replay-time ADC cap, DESIGN.md §6i). `None` — and
    /// `Some(&AnalogMode::ideal())`, by construction — step
    /// bit-identically to the exact path, lane for lane.
    pub fn on_chip_analog(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        capacity: usize,
        analog: Option<&AnalogMode>,
    ) -> BatchDecodeEngine {
        let chip = FunctionalChip::program_rect_analog(
            &model.cfg,
            &model.ops,
            &model.weights,
            &params,
            strategy,
            analog,
        );
        Self::with_backend(model, ParaBackend::Chip(Box::new(chip)), params, capacity)
    }

    /// Batched engine whose decoder layers are sharded across (up to)
    /// `shards` pipeline-stage chips under one mapping strategy
    /// (`sim::shard`, DESIGN.md §6f). Functionally bit-identical to
    /// [`BatchDecodeEngine::on_chip`] — tokens, logits and KV contents
    /// match lane for lane (`tests/prop_shard.rs`) — while every step
    /// additionally records a per-stage pipeline timeline into
    /// [`BatchDecodeEngine::pipeline_stats`].
    pub fn sharded(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        capacity: usize,
        shards: usize,
    ) -> BatchDecodeEngine {
        Self::sharded_analog(model, params, strategy, capacity, shards, None)
    }

    /// [`BatchDecodeEngine::sharded`] with opt-in analog realism: every
    /// stage chip is programmed under the same [`AnalogMode`]
    /// ([`ShardedBackend::program_analog`]). Ideal settings are
    /// bit-identical to the exact sharded path (and so to mono replay);
    /// noisy settings corrupt per stage chip, so they only promise
    /// determinism across reprogrammings, not bit-equality to mono.
    pub fn sharded_analog(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        capacity: usize,
        shards: usize,
        analog: Option<&AnalogMode>,
    ) -> BatchDecodeEngine {
        assert!(capacity >= 1, "need at least one sequence slot");
        let sharded =
            ShardedBackend::program_analog(&model, &params, strategy, shards, capacity, analog);
        let slots: Vec<BatchSlot> =
            (0..capacity).map(|_| BatchSlot::new(&model.cfg)).collect();
        let ws = ChunkWorkspace::new(&model.cfg, capacity);
        BatchDecodeEngine {
            ws,
            model,
            backend: EngineBackend::Sharded(sharded),
            params,
            slots,
            pipeline: PipelineStats::default(),
        }
    }

    fn with_backend(
        model: DecodeModel,
        mut backend: ParaBackend,
        params: CimParams,
        capacity: usize,
    ) -> BatchDecodeEngine {
        assert!(capacity >= 1, "need at least one sequence slot");
        let slots: Vec<BatchSlot> =
            (0..capacity).map(|_| BatchSlot::new(&model.cfg)).collect();
        // pre-grow the chip's batched scratch so the first step at the
        // slot-pool width allocates nothing
        if let ParaBackend::Chip(chip) = &mut backend {
            chip.warm_batch(capacity);
        }
        let ws = ChunkWorkspace::new(&model.cfg, capacity);
        BatchDecodeEngine {
            ws,
            model,
            backend: EngineBackend::Mono(backend),
            params,
            slots,
            pipeline: PipelineStats::default(),
        }
    }

    /// Total sequence slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Currently occupied slots.
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }

    /// Whether `slot` currently holds an in-flight sequence.
    pub fn is_active(&self, slot: usize) -> bool {
        self.slots[slot].active
    }

    /// Claim a free slot for a new sequence (cleared KV/trace/logits);
    /// `None` when every slot is occupied.
    pub fn try_admit(&mut self) -> Option<usize> {
        let s = self.slots.iter().position(|s| !s.active)?;
        let slot = &mut self.slots[s];
        slot.active = true;
        slot.clear();
        Some(s)
    }

    /// Evict a slot (finished or cancelled sequence). All request state
    /// is wiped immediately, so a later occupant can never observe it.
    pub fn release(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        s.active = false;
        s.clear();
    }

    /// Cached positions of one slot.
    pub fn kv_len(&self, slot: usize) -> usize {
        self.slots[slot].kv_len()
    }

    /// One slot's key/value cache (read-only — for cross-checking
    /// chunked prefill against token-by-token ingestion).
    pub fn kv(&self, slot: usize) -> &KvCache {
        &self.slots[slot].kv
    }

    /// Roll one slot's KV cache back to `len` positions — the
    /// speculative-decoding rejection path (`sim::speculate`): a verify
    /// chunk's rejected tail is dropped so the next chunk re-enters at
    /// the first wrong position. The slot's cost trace is deliberately
    /// *not* rolled back — rejected lanes paid their analog/ADC work
    /// and stay on the bill (DESIGN.md §6d).
    pub fn truncate_kv(&mut self, slot: usize, len: usize) {
        self.slots[slot].kv.truncate(len);
    }

    /// Splice the first `len` cached positions of `src` into a freshly
    /// admitted slot — the shared-prefix KV reuse path (DESIGN.md §6g).
    /// The slot then continues from position `len` exactly as if it had
    /// prefilled those tokens itself: a position's K/V depend only on
    /// the tokens up to it, so under an identical leading window the
    /// spliced state is bitwise the state cold prefill would have built
    /// (`tests/prop_prefix_cache.rs`). Splicing is admission-time only:
    /// the slot must be active and still empty, the donor must span the
    /// same layers, and the spliced length must fit the context window.
    /// The slot's cost trace is untouched — cached positions ran (and
    /// were billed) on the donor's pass, not this one.
    pub fn splice_kv(&mut self, slot: usize, src: &KvCache, len: usize) {
        let s = &mut self.slots[slot];
        assert!(s.active, "KV splice into an unadmitted slot {slot}");
        assert!(
            s.kv.is_empty(),
            "KV splice needs a fresh slot, {slot} has {} cached positions",
            s.kv.len()
        );
        assert_eq!(
            src.layers(),
            s.kv.layers(),
            "donor cache layer count diverges from the engine's"
        );
        assert!(
            len <= src.len(),
            "splice_kv({len}) exceeds the donor's {} cached positions",
            src.len()
        );
        assert!(
            len <= self.model.cfg.seq,
            "spliced prefix {len} exceeds the context window {}",
            self.model.cfg.seq
        );
        for layer in 0..src.layers() {
            for pos in 0..len {
                s.kv.push(
                    layer,
                    src.key(layer, pos).to_vec(),
                    src.value(layer, pos).to_vec(),
                );
            }
        }
    }

    /// LM-head logits of the slot's latest stepped position (borrowed
    /// from the slot's buffer — valid until its next step).
    pub fn logits(&self, slot: usize) -> &[f32] {
        &self.slots[slot].logits
    }

    /// Per-position logits of the latest [`BatchDecodeEngine::step_chunks`]
    /// call, by flattened lane index: groups in call order, chunk
    /// positions in order within each group (a step of
    /// `[(s0, &[a, b]), (s1, &[c])]` exposes lanes `0 -> a, 1 -> b,
    /// 2 -> c`). Valid until the next step. This is how the serving
    /// layer streams every prompt position's logits out of a chunk.
    pub fn lane_logits(&self, lane: usize) -> &[f32] {
        self.ws.lane_logits(lane)
    }

    /// Move the slot's accumulated per-position costs out (one entry
    /// per stepped position since admission).
    pub fn take_trace(&mut self, slot: usize) -> Vec<Cost> {
        std::mem::take(&mut self.slots[slot].trace.per_token)
    }

    /// Borrow the slot's accumulated per-position costs without
    /// draining them — tracing reads per-step deltas off this between
    /// steps; [`BatchDecodeEngine::take_trace`] still drains at
    /// completion.
    pub fn slot_trace(&self, slot: usize) -> &[Cost] {
        &self.slots[slot].trace.per_token
    }

    /// The chip's mapping (None for the reference backend). A sharded
    /// engine reports its 1-chip *reference* mapping — the one its
    /// per-position cost records are priced with.
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        match &self.backend {
            EngineBackend::Mono(ParaBackend::Chip(c)) => Some(&c.mapping),
            EngineBackend::Mono(ParaBackend::Reference) => None,
            EngineBackend::Sharded(sb) => Some(sb.full_mapping()),
        }
    }

    /// Select the chip's pass-table replay encoding (no-op on the
    /// reference backend; applied to every stage chip when sharded).
    /// Bit-identical either way; used by the bench to compare bit-block
    /// replay against the index-list baseline.
    pub fn set_replay_mode(&mut self, mode: crate::sim::exec::ReplayMode) {
        match &mut self.backend {
            EngineBackend::Mono(ParaBackend::Chip(chip)) => chip.set_replay_mode(mode),
            EngineBackend::Mono(ParaBackend::Reference) => {}
            EngineBackend::Sharded(sb) => sb.set_replay_mode(mode),
        }
    }

    /// Pipeline stages backing this engine (1 on the mono path).
    pub fn stage_count(&self) -> usize {
        match &self.backend {
            EngineBackend::Mono(_) => 1,
            EngineBackend::Sharded(sb) => sb.stage_count(),
        }
    }

    /// Contiguous layer range `[lo, hi)` of each pipeline stage (the
    /// whole model as one range on the mono path).
    pub fn stage_ranges(&self) -> Vec<(usize, usize)> {
        match &self.backend {
            EngineBackend::Mono(_) => vec![(0, self.model.cfg.dec_layers)],
            EngineBackend::Sharded(sb) => sb.ranges(),
        }
    }

    /// Accumulated pipeline observability (empty/default on the mono
    /// path — `steps` stays 0).
    pub fn pipeline_stats(&self) -> &PipelineStats {
        &self.pipeline
    }

    /// Move the accumulated pipeline stats out, resetting the window
    /// (the serving layer snapshots per scrape).
    pub fn take_pipeline_stats(&mut self) -> PipelineStats {
        std::mem::take(&mut self.pipeline)
    }

    /// Advance the listed slots by one token each (`(slot, token)`
    /// pairs; slots must be active and distinct, any subset and order) —
    /// the pure-decode special case of [`BatchDecodeEngine::step_chunks`]
    /// with every chunk of length 1.
    pub fn step(&mut self, inputs: &[(usize, i32)]) {
        let toks: Vec<[i32; 1]> = inputs.iter().map(|&(_, t)| [t]).collect();
        let groups: Vec<(usize, &[i32])> = inputs
            .iter()
            .zip(&toks)
            .map(|(&(s, _), t)| (s, &t[..]))
            .collect();
        self.step_chunks(&groups);
    }

    /// Advance each listed slot by its token chunk (`(slot, tokens)`
    /// pairs; slots must be active and distinct, chunks non-empty, and
    /// each slot's cache + chunk must fit the context window). Every
    /// Para matmul runs once, batched over **lanes = Σ chunk lengths**;
    /// everything order-dependent (LayerNorm, causal attention against
    /// the slot's own cache prefix, residuals, LM head) runs lane by
    /// lane — see `sim::prefill::chunk_step`. Appends K/V per position
    /// and records a per-position cost at the position's own KV length.
    pub fn step_chunks(&mut self, inputs: &[(usize, &[i32])]) {
        assert!(!inputs.is_empty(), "step needs at least one active slot");
        for (i, &(si, toks)) in inputs.iter().enumerate() {
            assert!(si < self.slots.len(), "slot {si} out of range");
            assert!(self.slots[si].active, "step on inactive slot {si}");
            assert!(!toks.is_empty(), "empty token chunk for slot {si}");
            assert!(
                !inputs[..i].iter().any(|&(sj, _)| sj == si),
                "duplicate slot {si} in one step"
            );
            let base = self.slots[si].kv_len();
            assert!(
                base + toks.len() <= self.model.cfg.seq,
                "slot {si}: request exceeds the context window (cached {base} + \
                 chunk {} > seq {}) — reject at admission/validation time",
                toks.len(),
                self.model.cfg.seq
            );
        }
        let BatchDecodeEngine {
            model,
            backend,
            params,
            slots,
            ws,
            pipeline,
        } = self;
        match backend {
            EngineBackend::Mono(pb) => {
                prefill::chunk_step(model, pb, params, slots, ws, inputs);
            }
            EngineBackend::Sharded(sb) => {
                let timeline = sharded_chunk_step(model, sb, params, slots, ws, inputs);
                pipeline.record(timeline);
            }
        }
    }

    /// Greedy generation of a whole request list through the slot pool
    /// with continuous batching and token-by-token prompt feeding —
    /// [`BatchDecodeEngine::generate_batch_chunked`] with chunk 1.
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
    ) -> Vec<DecodeResult> {
        self.generate_batch_chunked(prompts, n_tokens, 1)
    }

    /// Greedy generation of a whole request list through the slot pool
    /// with continuous batching **and chunked prefill**: requests are
    /// admitted into free slots as they open up (more requests than
    /// slots exercises mid-run admission), each admitted request ingests
    /// its prompt `chunk` positions per step — sharing every batched
    /// replay with its neighbours' decode lanes, which always keep their
    /// lane (`sim::prefill::allocate_chunks` bounds prefill so decode is
    /// never starved) — then argmax-extends for `n_tokens`; finished
    /// slots are evicted and refilled without stalling in-flight
    /// neighbours. Per request the semantics (and, bit for bit, the
    /// tokens) equal [`DecodeEngine::generate`] on a fresh single-stream
    /// engine, for every chunk size.
    pub fn generate_batch_chunked(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
        chunk: usize,
    ) -> Vec<DecodeResult> {
        let chunk = chunk.max(1);
        for (ri, p) in prompts.iter().enumerate() {
            assert!(!p.is_empty(), "request {ri}: need at least one prompt token");
            assert_fits_context(&self.model.cfg, p.len(), n_tokens);
        }
        let cap = self.slots.len();
        // start clean: evict anything left over from a previous run
        for s in 0..cap {
            if self.slots[s].active {
                self.release(s);
            }
        }
        let mut results: Vec<DecodeResult> = prompts
            .iter()
            .map(|_| DecodeResult {
                tokens: Vec::with_capacity(n_tokens),
                per_token: Vec::new(),
            })
            .collect();
        // per-slot (request index, positions fed so far)
        let mut running: Vec<Option<(usize, usize)>> = vec![None; cap];
        let mut next_req = 0usize;
        // every decode lane always fits the budget; prefill shares the rest
        let lane_budget = cap.max(chunk);
        let mut decode_tok: Vec<[i32; 1]> = vec![[0]; cap];
        // per-step plan buffers, hoisted and reused (the `groups` slice
        // vector itself is per-iteration: it borrows `decode_tok`, which
        // the next iteration rewrites)
        let mut plan: Vec<(usize, usize)> = Vec::with_capacity(cap); // (slot, lanes)
        let mut wants: Vec<usize> = Vec::with_capacity(cap);
        let mut decode_count: usize;
        loop {
            while next_req < prompts.len() {
                match self.try_admit() {
                    Some(s) => {
                        running[s] = Some((next_req, 0));
                        next_req += 1;
                    }
                    None => break,
                }
            }
            // classify in-flight slots: decode lanes (1 token, argmax)
            // first, then prefilling slots (want up to `chunk` prompt
            // positions); `plan` holds (slot, chunk length) in step order
            plan.clear();
            wants.clear();
            decode_count = 0;
            for (s, run) in running.iter().enumerate() {
                if let Some((req, fed)) = *run {
                    if fed >= prompts[req].len() {
                        plan.push((s, 1));
                        decode_count += 1;
                    }
                }
            }
            for (s, run) in running.iter().enumerate() {
                if let Some((req, fed)) = *run {
                    let plen = prompts[req].len();
                    if fed < plen {
                        plan.push((s, 0));
                        wants.push((plen - fed).min(chunk));
                    }
                }
            }
            if plan.is_empty() {
                break;
            }
            let budget_left = lane_budget.saturating_sub(decode_count);
            let alloc = allocate_chunks(&wants, budget_left);
            for (p, &c) in plan[decode_count..].iter_mut().zip(&alloc) {
                p.1 = c;
            }
            // argmax continuations — exactly DecodeEngine::generate's rule
            for &(s, _) in &plan[..decode_count] {
                let (req, _) = running[s].expect("decode slot is running");
                let t = argmax(self.logits(s)) as i32;
                results[req].tokens.push(t);
                decode_tok[s] = [t];
            }
            {
                let groups: Vec<(usize, &[i32])> = plan
                    .iter()
                    .enumerate()
                    .map(|(i, &(s, c))| {
                        if i < decode_count {
                            (s, &decode_tok[s][..])
                        } else {
                            let (req, fed) = running[s].expect("prefill slot is running");
                            (s, &prompts[req][fed..fed + c])
                        }
                    })
                    .collect();
                self.step_chunks(&groups);
            }
            for &(s, c) in &plan {
                let (req, fed) = running[s].expect("stepped slot is running");
                let done = fed + c;
                if done == prompts[req].len() + n_tokens {
                    results[req].per_token = self.take_trace(s);
                    self.release(s);
                    running[s] = None;
                } else {
                    running[s] = Some((req, done));
                }
            }
        }
        results
    }
}

/// Digital multi-head attention of one query against the KV cache, into
/// caller-owned context/score scratch (every entry overwritten). Causal
/// masking is the caller's prefix bound: pass `keys[..pos + 1]` /
/// `values[..pos + 1]` and later positions simply do not exist here.
pub(crate) fn attend_into(
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
    heads: usize,
    dh: usize,
    scores: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    let t = keys.len();
    let scale = 1.0 / (dh as f32).sqrt();
    ctx.fill(0.0);
    scores.resize(t, 0.0);
    for h in 0..heads {
        let o = h * dh;
        for (i, k) in keys.iter().enumerate() {
            let mut s = 0.0f32;
            for j in 0..dh {
                s += q[o + j] * k[o + j];
            }
            scores[i] = s * scale;
        }
        let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        for (i, v) in values.iter().enumerate() {
            let a = scores[i] * inv;
            for j in 0..dh {
                ctx[o + j] += a * v[o + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn model_synthesis_is_deterministic() {
        let a = DecodeModel::synth(tiny(), 7);
        let b = DecodeModel::synth(tiny(), 7);
        assert_eq!(a.weights.len(), b.weights.len());
        for (wa, wb) in a.weights.iter().zip(&b.weights) {
            for (ta, tb) in wa.tiles.iter().zip(&wb.tiles) {
                assert_eq!(ta.l.data, tb.l.data);
                assert_eq!(ta.r.data, tb.r.data);
            }
        }
        assert_eq!(a.embedding.data, b.embedding.data);
        let c = DecodeModel::synth(tiny(), 8);
        assert_ne!(a.embedding.data, c.embedding.data);
    }

    #[test]
    fn reference_engine_generates_and_caches() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 3));
        let r = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens.len(), 8);
        assert_eq!(eng.kv_len(), 3 + 8);
        let vocab = tiny().vocab as i32;
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
        // regeneration from the same prompt is identical
        let r2 = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens, r2.tokens);
    }

    #[test]
    fn kv_cache_matches_full_recompute() {
        // Scoring [t0..t3] incrementally must give the same final-position
        // logits as re-running the prefix from scratch.
        let model = DecodeModel::synth(tiny(), 11);
        let mut eng = DecodeEngine::reference(model);
        let toks = [5i32, 9, 2, 40];
        let (all, _) = eng.score(&toks);
        let vocab = tiny().vocab;
        let last = &all[3 * vocab..4 * vocab];
        // recompute: fresh engine, same sequence
        let mut eng2 = DecodeEngine::reference(DecodeModel::synth(tiny(), 11));
        let mut logits = Vec::new();
        for &t in &toks {
            logits = eng2.forward(t).to_vec();
        }
        assert_eq!(last, logits.as_slice());
    }

    #[test]
    fn chip_engine_records_costs_reference_does_not() {
        let params = CimParams::default();
        let model = DecodeModel::synth(tiny(), 5);
        let mut chip = DecodeEngine::on_chip(model, params, Strategy::SparseMap);
        let r = chip.generate(&[1, 2], 4);
        assert_eq!(r.per_token.len(), 6); // 2 prompt + 4 generated
        assert!(r.per_token.iter().all(|c| c.latency.critical_ns() > 0.0));
        // MHA share grows with the cache
        assert!(
            r.per_token.last().unwrap().latency.mha_ns
                > r.per_token.first().unwrap().latency.mha_ns
        );
        // the result owns the run's trace (moved, not copied)
        assert_eq!(chip.trace.tokens(), 0);
        let mut reference = DecodeEngine::reference(DecodeModel::synth(tiny(), 5));
        let rr = reference.generate(&[1, 2], 4);
        assert!(rr.per_token.iter().all(|c| c.latency.critical_ns() == 0.0));
        assert!(chip.mapping().is_some());
        assert!(reference.mapping().is_none());
    }

    #[test]
    fn engine_reuse_equals_fresh_engine() {
        // Slot-reuse regression (ISSUE 3): generating on a dirtied
        // engine must equal a fresh engine token-for-token — reset
        // leaves no KV, trace, score-window or logit residue behind.
        let params = CimParams::default();
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut used = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 21),
                params.clone(),
                strategy,
            );
            let _ = used.generate(&[9, 1, 7, 13], 6); // dirty KV/trace/logits
            let reused = used.generate(&[3, 4], 6);
            let mut fresh = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 21),
                params.clone(),
                strategy,
            );
            let direct = fresh.generate(&[3, 4], 6);
            assert_eq!(reused.tokens, direct.tokens, "{strategy:?}: reuse drifted");
            assert_eq!(reused.per_token.len(), direct.per_token.len());
        }
    }

    #[test]
    fn batch_step_logits_match_single_forward_bitwise() {
        // Teacher-forced: two ragged slots stepped together produce, at
        // every position, exactly the single-stream forward's logits.
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 3), 2);
        let s0 = be.try_admit().unwrap();
        let s1 = be.try_admit().unwrap();
        assert!(be.try_admit().is_none(), "capacity 2 means 2 slots");
        let seqs = [vec![5i32, 9, 2], vec![8i32, 1, 30]];
        let mut singles = [
            DecodeEngine::reference(DecodeModel::synth(tiny(), 3)),
            DecodeEngine::reference(DecodeModel::synth(tiny(), 3)),
        ];
        for t in 0..3 {
            be.step(&[(s0, seqs[0][t]), (s1, seqs[1][t])]);
            for (i, &s) in [s0, s1].iter().enumerate() {
                let want = singles[i].forward(seqs[i][t]).to_vec();
                assert_eq!(be.logits(s), want.as_slice(), "slot {i} pos {t}");
            }
        }
        // evict slot 0; the freed slot readmits clean while slot 1 keeps
        // its cache (ragged coexistence)
        be.release(s0);
        assert_eq!(be.occupancy(), 1);
        let s2 = be.try_admit().unwrap();
        assert_eq!(s2, s0, "freed slot is reusable");
        assert_eq!(be.kv_len(s2), 0, "readmitted slot starts empty");
        assert_eq!(be.kv_len(s1), 3, "neighbour cache untouched");
    }

    #[test]
    fn generate_batch_matches_single_stream_engines() {
        let params = CimParams::default();
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(tiny(), 5),
            params.clone(),
            Strategy::DenseMap,
            3,
        );
        let prompts = vec![vec![1, 2, 3], vec![7, 8], vec![40, 41, 42, 43]];
        let results = be.generate_batch(&prompts, 5);
        for (p, r) in prompts.iter().zip(&results) {
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 5),
                params.clone(),
                Strategy::DenseMap,
            );
            let want = single.generate(p, 5);
            assert_eq!(r.tokens, want.tokens, "prompt {p:?}");
            assert_eq!(r.per_token.len(), want.per_token.len());
        }
    }

    #[test]
    fn generate_batch_admits_beyond_capacity() {
        // 5 requests through 2 slots: finished slots are evicted and
        // refilled mid-run without disturbing in-flight neighbours.
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 9), 2);
        let prompts: Vec<Vec<i32>> = (0..5)
            .map(|i| (0..(i % 3 + 1)).map(|j| (i * 13 + j * 7 + 1) as i32).collect())
            .collect();
        let results = be.generate_batch(&prompts, 4);
        assert_eq!(results.len(), 5);
        assert_eq!(be.occupancy(), 0, "all slots evicted at end");
        assert!(results.iter().all(|r| r.tokens.len() == 4));
        for (p, r) in prompts.iter().zip(&results) {
            let mut single = DecodeEngine::reference(DecodeModel::synth(tiny(), 9));
            assert_eq!(r.tokens, single.generate(p, 4).tokens, "prompt {p:?}");
        }
    }

    #[test]
    fn chunked_prefill_equals_token_by_token_generate() {
        // The PR-4 acceptance property at unit granularity: one request
        // prefilled 4 positions per replay generates exactly the tokens
        // (and per-position costs) of token-by-token ingestion.
        let params = CimParams::default();
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut be = BatchDecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 31),
                params.clone(),
                strategy,
                1,
            );
            let prompt: Vec<i32> = (0..10).map(|i| (i * 11 + 3) as i32).collect();
            let chunked = be.generate_batch_chunked(&[prompt.clone()], 6, 4);
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 31),
                params.clone(),
                strategy,
            );
            let want = single.generate(&prompt, 6);
            assert_eq!(chunked[0].tokens, want.tokens, "{strategy:?}");
            assert_eq!(chunked[0].per_token.len(), want.per_token.len());
            for (a, w) in chunked[0].per_token.iter().zip(&want.per_token) {
                assert_eq!(a.latency.critical_ns(), w.latency.critical_ns());
                assert_eq!(a.energy.total_nj(), w.energy.total_nj());
            }
        }
    }

    #[test]
    fn mixed_decode_and_prefill_step_is_per_lane_identical() {
        // One slot mid-stream decodes a single token while a freshly
        // admitted neighbour prefills 3 positions in the same step; both
        // must be bit-identical to their single-stream twins.
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 13), 2);
        let s0 = be.try_admit().unwrap();
        be.step_chunks(&[(s0, &[4i32, 9][..])]); // slot 0 now has 2 cached positions
        let s1 = be.try_admit().unwrap();
        be.step_chunks(&[(s0, &[17i32][..]), (s1, &[7i32, 21, 2][..])]);
        let mut e0 = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        e0.forward(4);
        e0.forward(9);
        let want0 = e0.forward(17).to_vec();
        assert_eq!(be.logits(s0), want0.as_slice(), "decode lane drifted");
        let mut e1 = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        e1.forward(7);
        e1.forward(21);
        let want1 = e1.forward(2).to_vec();
        assert_eq!(be.logits(s1), want1.as_slice(), "prefill lane drifted");
        // per-position lane logits follow flattened input order
        assert_eq!(be.lane_logits(0), want0.as_slice());
        assert_eq!(be.lane_logits(3), want1.as_slice());
        // KV caches match position by position
        for l in 0..tiny().dec_layers {
            for pos in 0..3 {
                assert_eq!(be.kv(s1).key(l, pos), e1.kv_cache().key(l, pos));
                assert_eq!(be.kv(s1).value(l, pos), e1.kv_cache().value(l, pos));
            }
        }
    }

    #[test]
    fn slot_truncate_rolls_back_to_a_clean_prefix() {
        // the speculative rollback primitive at the batch-engine level:
        // feed a chunk, roll back past a "rejected" tail, re-feed — the
        // cache and logits must be bitwise the straight-through run's
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 13), 1);
        let s = be.try_admit().unwrap();
        be.step_chunks(&[(s, &[4i32, 9, 17, 21][..])]);
        be.truncate_kv(s, 2); // drop the speculative tail [17, 21]
        assert_eq!(be.kv_len(s), 2);
        be.step_chunks(&[(s, &[30i32][..])]);
        let mut single = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        single.forward(4);
        single.forward(9);
        let want = single.forward(30).to_vec();
        assert_eq!(be.logits(s), want.as_slice(), "rollback left residue");
        for l in 0..tiny().dec_layers {
            for pos in 0..3 {
                assert_eq!(be.kv(s).key(l, pos), single.kv_cache().key(l, pos));
                assert_eq!(be.kv(s).value(l, pos), single.kv_cache().value(l, pos));
            }
        }
    }

    #[test]
    #[should_panic(expected = "exceeds the context window")]
    fn generate_rejects_overlong_requests() {
        // ISSUE-4 satellite regression: prompt + generation beyond seq
        // must be rejected loudly, not silently clamped to the last
        // position.
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 3));
        let prompt: Vec<i32> = (0..4).collect();
        let _ = eng.generate(&prompt, tiny().seq); // 4 + seq > seq
    }

    #[test]
    #[should_panic(expected = "exceeds the context window")]
    fn step_chunks_rejects_overflowing_chunk() {
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 3), 1);
        let s = be.try_admit().unwrap();
        let toks: Vec<i32> = vec![1; tiny().seq + 1];
        be.step_chunks(&[(s, &toks[..])]);
    }

    #[test]
    fn context_window_boundary_is_accepted() {
        // Exactly seq positions must work (the rejection is strict >).
        let cfg = tiny();
        let mut eng = DecodeEngine::reference(DecodeModel::synth(cfg.clone(), 3));
        let prompt: Vec<i32> = (0..4).collect();
        let r = eng.generate(&prompt, cfg.seq - 4);
        assert_eq!(r.tokens.len(), cfg.seq - 4);
        assert_eq!(eng.kv_len(), cfg.seq);
    }

    #[test]
    fn score_is_reset_safe() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        let toks = vec![7i32; tiny().seq];
        let (a, _) = eng.score(&toks);
        let (b, _) = eng.score(&toks);
        assert_eq!(a, b, "score must be independent of prior requests");
        assert_eq!(a.len(), tiny().seq * tiny().vocab);
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
