//! Autoregressive decode engine: a full decoder-only transformer forward
//! pass, token by token with a growing KV cache, whose *parameterized*
//! matmuls run on the emulated crossbar chip ([`FunctionalChip`]) under
//! any of the three mapping strategies — the workload the paper actually
//! measures (Fig. 7/8's token-streaming decode regime), not an isolated
//! matvec.
//!
//! Split of responsibilities (paper Fig. 2b):
//! * **Para ops** (`wq/wk/wv/wo/ffn1/ffn2`) — weight-stationary in CIM
//!   arrays; executed by `FunctionalChip::run_op_into` replaying the
//!   compiled plan (`scheduler::plan`) with scheduler-issued
//!   row-activation masks, pre-rotated column conversion and stride
//!   permutations.
//! * **NonPara ops** (attention scores `qk` and context `av`) — digital,
//!   on the MHA unit: computed here in f32 against the KV cache; their
//!   cost is `trace::mha_token_cost` (grows with the cache).
//! * Everything else (LayerNorm, GeLU, residuals, embedding/LM head) —
//!   DPU vector ops, identical across backends.
//!
//! The steady-state token loop is allocation-free: the engine owns one
//! [`EngineBufs`] set of activation buffers (reused every token, every
//! request), the chip owns its pass scratch, and the only per-token heap
//! traffic is the KV-cache append — state, not scratch.
//!
//! Because the chip's Monarch passes replay the factored reference's f32
//! operations in the same order, SparseMap/DenseMap decode is
//! bit-identical to the [`RectMonarch`] reference model; Linear programs
//! the *dense materialization* of the same operator and agrees to float
//! tolerance — so greedy token sequences match across all three
//! strategies (tier-1 `tests/integration_decode.rs`).
//!
//! [`BatchDecodeEngine`] extends the same loop to a slot pool: B
//! sequences share one programmed chip, every Para op replays its pass
//! tables once per step for the whole batch
//! (`FunctionalChip::run_op_batch_into`, stride-B interleaved lanes),
//! and slots admit/evict between steps (continuous batching). Each lane
//! is bit-identical to the single-stream path, so batched logits never
//! depend on batchmates (`tests/prop_batch_decode.rs`).

use std::collections::HashMap;

use crate::cim::{CimParams, Cost};
use crate::mapping::Strategy;
use crate::model::{para_ops, MatmulOp, ModelConfig};
use crate::monarch::{MonarchMatrix, RectMonarch};
use crate::sim::exec::FunctionalChip;
use crate::sim::trace::{decode_token_cost, DecodeTrace};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Parameterized-op indices of one decoder layer (into the para-op list).
#[derive(Clone, Copy, Debug)]
struct LayerOps {
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ffn1: usize,
    ffn2: usize,
}

/// A synthetic Monarch decoder-only transformer: every Para weight is a
/// tile grid of Monarch factors (deterministically seeded), plus token
/// embeddings, learned positional embeddings and an untied LM head (a
/// tied head makes a random-weight model echo its input token forever —
/// untied gives non-degenerate greedy sequences, with comfortable
/// argmax margins, ~0.01 at the tiny config).
pub struct DecodeModel {
    pub cfg: ModelConfig,
    pub ops: Vec<MatmulOp>,
    pub weights: Vec<RectMonarch>,
    /// Token embedding table (vocab x d).
    pub embedding: Matrix,
    /// Learned positional embeddings (seq x d).
    pub positional: Matrix,
    /// Untied LM head (vocab x d).
    pub lm_head: Matrix,
    layers: Vec<LayerOps>,
}

/// Variance-preserving random Monarch tile (factors scaled by 1/sqrt(b)).
fn scaled_monarch(b: usize, rng: &mut Pcg32) -> MonarchMatrix {
    let mut m = MonarchMatrix::randn(b, rng);
    let s = 1.0 / (b as f32).sqrt();
    for v in m.l.data.iter_mut() {
        *v *= s;
    }
    for v in m.r.data.iter_mut() {
        *v *= s;
    }
    m
}

impl DecodeModel {
    /// Deterministically synthesize weights for a decoder-only config.
    /// Takes the config by value — callers that keep one pass a clone,
    /// everyone else just moves it in.
    pub fn synth(cfg: ModelConfig, seed: u64) -> DecodeModel {
        assert_eq!(
            cfg.enc_layers, 0,
            "decode engine targets decoder-only models (got {})",
            cfg.name
        );
        assert!(cfg.dec_layers > 0, "model has no decoder layers");
        let d = cfg.d_model;
        let b = cfg.monarch_b();
        let ops = para_ops(&cfg);
        let weights: Vec<RectMonarch> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                let mut rng = Pcg32::stream(seed, i as u64);
                let tiles = op.rows.div_ceil(d) * op.cols.div_ceil(d);
                RectMonarch {
                    rows: op.rows,
                    cols: op.cols,
                    n: d,
                    tiles: (0..tiles).map(|_| scaled_monarch(b, &mut rng)).collect(),
                }
            })
            .collect();
        let by_name: HashMap<&str, usize> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| (op.name.as_str(), i))
            .collect();
        let layers = (0..cfg.dec_layers)
            .map(|l| {
                let idx = |w: &str| -> usize {
                    *by_name
                        .get(format!("dec{l}.{w}").as_str())
                        .unwrap_or_else(|| panic!("missing op dec{l}.{w}"))
                };
                LayerOps {
                    wq: idx("wq"),
                    wk: idx("wk"),
                    wv: idx("wv"),
                    wo: idx("wo"),
                    ffn1: idx("ffn1"),
                    ffn2: idx("ffn2"),
                }
            })
            .collect();
        let embedding = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0x5eed));
        let positional =
            Matrix::randn(cfg.seq, d, &mut Pcg32::stream(seed, 0x905e)).scale(0.1);
        let lm_head = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0xeadd));
        DecodeModel {
            cfg,
            ops,
            weights,
            embedding,
            positional,
            lm_head,
            layers,
        }
    }

    /// Reference Para matmul (`y = W x`) through the factored tiles.
    pub fn reference_matvec(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        self.weights[op_idx].matvec(x)
    }
}

/// Where the Para matmuls execute.
pub enum ParaBackend {
    /// Plain `RectMonarch::matvec` — the golden model.
    Reference,
    /// Emulated crossbar chip programmed under one mapping strategy.
    Chip(Box<FunctionalChip>),
}

impl ParaBackend {
    /// Execute `y = W x` for op `op_idx` into a caller buffer. The chip
    /// path replays the compiled plan allocation-free; the reference
    /// path runs the golden factored matvec.
    fn run_into(&mut self, model: &DecodeModel, op_idx: usize, x: &[f32], y: &mut [f32]) {
        match self {
            ParaBackend::Reference => {
                let r = model.reference_matvec(op_idx, x);
                y.copy_from_slice(&r);
            }
            ParaBackend::Chip(chip) => chip.run_op_into(op_idx, x, y),
        }
    }

    /// Batched form: `batch` stride-B interleaved input vectors through
    /// one plan replay (`xs[c * batch + l]` is lane `l`'s element `c`).
    /// The chip path amortizes every analog pass over the batch; the
    /// reference path runs the golden matvec lane by lane. Either way,
    /// lane `l` is bit-identical to a `run_into` call over lane `l`'s
    /// vector — the invariant batched decode rests on.
    fn run_batch_into(
        &mut self,
        model: &DecodeModel,
        op_idx: usize,
        batch: usize,
        xs: &[f32],
        ys: &mut [f32],
    ) {
        match self {
            ParaBackend::Reference => {
                let cols = model.ops[op_idx].cols;
                let mut x = vec![0.0f32; cols];
                for l in 0..batch {
                    for (c, xv) in x.iter_mut().enumerate() {
                        *xv = xs[c * batch + l];
                    }
                    let r = model.reference_matvec(op_idx, &x);
                    for (i, v) in r.iter().enumerate() {
                        ys[i * batch + l] = *v;
                    }
                }
            }
            ParaBackend::Chip(chip) => chip.run_op_batch_into(op_idx, batch, xs, ys),
        }
    }
}

/// Per-token activation buffers, allocated once per engine and reused
/// across tokens and requests (the serving worker keeps one engine, so
/// this scratch also persists across requests).
struct EngineBufs {
    /// Residual stream (d).
    h: Vec<f32>,
    /// LayerNorm output feeding the current sub-block (d).
    x: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context (d).
    ctx: Vec<f32>,
    o: Vec<f32>,
    /// FFN hidden (d_ff).
    f: Vec<f32>,
    g: Vec<f32>,
    /// Final LayerNorm output (d).
    hn: Vec<f32>,
    /// Attention score scratch (grows to the KV length; capacity
    /// reserved for the model's context window).
    scores: Vec<f32>,
    /// LM-head logits of the latest forwarded position (vocab).
    logits: Vec<f32>,
}

impl EngineBufs {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        Self {
            h: vec![0.0; d],
            x: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            o: vec![0.0; d],
            f: vec![0.0; cfg.d_ff],
            g: vec![0.0; d],
            hn: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            logits: vec![0.0; cfg.vocab],
        }
    }
}

/// The decode engine: owns the model, the Para backend, the KV cache and
/// the per-token scratch; generates tokens greedily and accounts
/// latency/energy per token.
pub struct DecodeEngine {
    pub model: DecodeModel,
    backend: ParaBackend,
    params: CimParams,
    /// Per-layer key/value cache (one d-vector per cached position).
    keys: Vec<Vec<Vec<f32>>>,
    values: Vec<Vec<Vec<f32>>>,
    pub trace: DecodeTrace,
    bufs: EngineBufs,
}

/// Result of one greedy generation run. The per-token costs are *moved*
/// out of the engine's trace (no deep copy): after `generate` returns,
/// the engine's own trace is empty until the next run records into it.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// The generated token ids (prompt excluded).
    pub tokens: Vec<i32>,
    /// Modeled cost of every processed position (prompt + generated).
    pub per_token: Vec<Cost>,
}

impl DecodeResult {
    /// Summed modeled cost of the whole run (the counterpart of
    /// `DecodeTrace::total` for the moved-out per-token records).
    pub fn total(&self) -> Cost {
        crate::sim::trace::sum_costs(&self.per_token)
    }
}

fn layer_norm_into(x: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (o, v) in out.iter_mut().zip(x) {
        *o = (v - mean) * inv;
    }
}

fn gelu(x: &mut [f32]) {
    // tanh approximation (identical across backends; DPU op)
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044_715 * u * u * u)).tanh());
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

impl DecodeEngine {
    /// Engine with the golden (non-CIM) Para backend.
    pub fn reference(model: DecodeModel) -> DecodeEngine {
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            model,
            backend: ParaBackend::Reference,
            params: CimParams::default(),
            keys: vec![Vec::new(); layers],
            values: vec![Vec::new(); layers],
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// Engine whose Para ops run on an emulated chip programmed with the
    /// given mapping strategy. Takes the CIM parameters by value (the
    /// engine stores them for per-token cost accounting).
    pub fn on_chip(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
    ) -> DecodeEngine {
        let chip = FunctionalChip::program_rect(
            &model.cfg,
            &model.ops,
            &model.weights,
            &params,
            strategy,
        );
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            model,
            backend: ParaBackend::Chip(Box::new(chip)),
            params,
            keys: vec![Vec::new(); layers],
            values: vec![Vec::new(); layers],
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// The chip's mapping (None for the reference backend).
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        match &self.backend {
            ParaBackend::Chip(c) => Some(&c.mapping),
            ParaBackend::Reference => None,
        }
    }

    /// Clear the KV cache, the trace and the stale per-request scratch
    /// (new sequence). After `reset` the engine is observationally
    /// indistinguishable from a freshly constructed one: the attention
    /// score window and the previous request's logits are wiped too, so
    /// a caller that reads logits before the first `forward` of the new
    /// request can never see the old request's distribution.
    pub fn reset(&mut self) {
        clear_request_state(
            &mut self.keys,
            &mut self.values,
            &mut self.trace,
            &mut self.bufs,
        );
    }

    /// Cached positions so far.
    pub fn kv_len(&self) -> usize {
        self.keys.first().map(|k| k.len()).unwrap_or(0)
    }

    /// Process one token at the next position; returns the LM-head
    /// logits (borrowed from the engine's reusable logit buffer — copy
    /// them out if they must outlive the next forward). Appends K/V to
    /// the cache and records the position's cost.
    pub fn forward(&mut self, token: i32) -> &[f32] {
        let pos = self.kv_len().min(self.model.cfg.seq - 1);
        let DecodeEngine {
            model,
            backend,
            params,
            keys,
            values,
            trace,
            bufs,
        } = self;
        let d = model.cfg.d_model;
        let heads = model.cfg.n_heads;
        let dh = model.cfg.d_head();
        let vocab = model.cfg.vocab;
        let n_layers = model.cfg.dec_layers;
        let tok = (token.max(0) as usize).min(vocab - 1);

        for ((hv, e), p) in bufs
            .h
            .iter_mut()
            .zip(model.embedding.row(tok))
            .zip(model.positional.row(pos))
        {
            *hv = e + p;
        }

        for l in 0..n_layers {
            let ops = model.layers[l];
            // --- self-attention sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.wq, &bufs.x, &mut bufs.q);
            backend.run_into(model, ops.wk, &bufs.x, &mut bufs.k);
            backend.run_into(model, ops.wv, &bufs.x, &mut bufs.v);
            keys[l].push(bufs.k.clone());
            values[l].push(bufs.v.clone());
            attend_into(
                &bufs.q,
                &keys[l],
                &values[l],
                heads,
                dh,
                &mut bufs.scores,
                &mut bufs.ctx,
            );
            backend.run_into(model, ops.wo, &bufs.ctx, &mut bufs.o);
            for (hv, ov) in bufs.h.iter_mut().zip(&bufs.o) {
                *hv += ov;
            }
            // --- feed-forward sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.ffn1, &bufs.x, &mut bufs.f);
            gelu(&mut bufs.f);
            backend.run_into(model, ops.ffn2, &bufs.f, &mut bufs.g);
            for (hv, gv) in bufs.h.iter_mut().zip(&bufs.g) {
                *hv += gv;
            }
        }

        // untied LM head over the final LayerNorm
        layer_norm_into(&bufs.h, &mut bufs.hn);
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for (t, lv) in bufs.logits.iter_mut().enumerate() {
            let row = model.lm_head.row(t);
            let mut acc = 0.0f32;
            for (r, x) in row.iter().zip(&bufs.hn) {
                acc += r * x;
            }
            *lv = acc * inv_sqrt_d;
        }

        // cost accounting: the mapped Para path + cache-sized MHA work
        let kv_len = keys.first().map(|k| k.len()).unwrap_or(0);
        let cost = match backend {
            ParaBackend::Chip(chip) => {
                decode_token_cost(&model.cfg, &chip.mapping, params, kv_len)
            }
            ParaBackend::Reference => Cost::default(),
        };
        trace.record(cost);
        &bufs.logits[..]
    }

    /// Greedy autoregressive generation: feed `prompt`, then emit
    /// `n_tokens` argmax continuations. The engine is reset first.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> DecodeResult {
        assert!(!prompt.is_empty(), "need at least one prompt token");
        self.reset();
        for &t in prompt {
            self.forward(t);
        }
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let next = argmax(&self.bufs.logits) as i32;
            tokens.push(next);
            self.forward(next);
        }
        DecodeResult {
            tokens,
            per_token: std::mem::take(&mut self.trace.per_token),
        }
    }

    /// Teacher-forced scoring: per-position logits (`seq * vocab`) for a
    /// full token window, plus the summed modeled cost — the CIM-sim
    /// serving contract (`coordinator::server::Backend::CimSim`).
    pub fn score(&mut self, tokens: &[i32]) -> (Vec<f32>, Cost) {
        self.reset();
        let vocab = self.model.cfg.vocab;
        let mut out = Vec::with_capacity(tokens.len() * vocab);
        for &t in tokens {
            let logits = self.forward(t);
            out.extend_from_slice(logits);
        }
        (out, self.trace.total())
    }
}

/// Wipe one request's state — KV cache, cost trace, attention score
/// window and logits. Single definition of "request state", shared by
/// [`DecodeEngine::reset`] and [`BatchSlot::clear`] so the two reuse
/// paths can never drift apart on what gets cleared.
fn clear_request_state(
    keys: &mut [Vec<Vec<f32>>],
    values: &mut [Vec<Vec<f32>>],
    trace: &mut DecodeTrace,
    bufs: &mut EngineBufs,
) {
    for k in keys.iter_mut() {
        k.clear();
    }
    for v in values.iter_mut() {
        v.clear();
    }
    trace.clear();
    bufs.scores.clear();
    bufs.logits.fill(0.0);
}

/// One sequence slot of the batched engine: its own KV cache, activation
/// buffers and per-position cost trace — everything request-private, so
/// slots at different positions (ragged lengths) coexist in one batch.
struct BatchSlot {
    /// Occupied by an in-flight sequence.
    active: bool,
    keys: Vec<Vec<Vec<f32>>>,
    values: Vec<Vec<Vec<f32>>>,
    bufs: EngineBufs,
    trace: DecodeTrace,
}

impl BatchSlot {
    fn new(cfg: &ModelConfig) -> Self {
        Self {
            active: false,
            keys: vec![Vec::new(); cfg.dec_layers],
            values: vec![Vec::new(); cfg.dec_layers],
            bufs: EngineBufs::new(cfg),
            trace: DecodeTrace::new(),
        }
    }

    fn kv_len(&self) -> usize {
        self.keys.first().map(|k| k.len()).unwrap_or(0)
    }

    /// Wipe all request state (KV cache, trace, score window, logits) so
    /// the next occupant starts from a provably clean slot.
    fn clear(&mut self) {
        clear_request_state(
            &mut self.keys,
            &mut self.values,
            &mut self.trace,
            &mut self.bufs,
        );
    }
}

// Stride-B staging accessors, named `fn`s so the function pointers get
// the usual elided-lifetime signatures.
fn buf_x(b: &EngineBufs) -> &[f32] {
    &b.x
}
fn buf_ctx(b: &EngineBufs) -> &[f32] {
    &b.ctx
}
fn buf_f(b: &EngineBufs) -> &[f32] {
    &b.f
}
fn buf_q_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.q
}
fn buf_k_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.k
}
fn buf_v_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.v
}
fn buf_o_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.o
}
fn buf_f_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.f
}
fn buf_g_mut(b: &mut EngineBufs) -> &mut [f32] {
    &mut b.g
}

/// Gather each lane's slot buffer into the stride-B interleaved staging
/// buffer: `xb[k * batch + l]` = element `k` of lane `l`'s vector.
fn pack_lanes(
    xb: &mut [f32],
    width: usize,
    slots: &[BatchSlot],
    lanes: &[usize],
    get: fn(&EngineBufs) -> &[f32],
) {
    let batch = lanes.len();
    for (l, &si) in lanes.iter().enumerate() {
        let src = get(&slots[si].bufs);
        for k in 0..width {
            xb[k * batch + l] = src[k];
        }
    }
}

/// Scatter the stride-B interleaved landing buffer back into each
/// lane's slot buffer (inverse of [`pack_lanes`]).
fn unpack_lanes(
    yb: &[f32],
    width: usize,
    slots: &mut [BatchSlot],
    lanes: &[usize],
    get: fn(&mut EngineBufs) -> &mut [f32],
) {
    let batch = lanes.len();
    for (l, &si) in lanes.iter().enumerate() {
        let dst = get(&mut slots[si].bufs);
        for k in 0..width {
            dst[k] = yb[k * batch + l];
        }
    }
}

/// Batched decode engine: a fixed set of sequence slots sharing ONE
/// programmed chip. Each [`BatchDecodeEngine::step`] advances any subset
/// of the slots by one token, replaying every Para op's compiled pass
/// tables once for the whole batch (`FunctionalChip::run_op_batch_into`)
/// — the weight-stationary amortization that turns the memory-bound
/// decode stage into a throughput-oriented serving core. Slots are
/// request-private (own KV cache, own [`EngineBufs`]), may sit at
/// different positions (ragged lengths), and can be admitted/evicted
/// between steps without touching in-flight neighbours (continuous
/// batching, `coordinator::server`).
///
/// Because every lane of the batched replay is bit-identical to the
/// single-stream path, a slot's logits never depend on its batchmates:
/// any interleaving of admissions/evictions produces exactly the tokens
/// of independent [`DecodeEngine`]s (`tests/prop_batch_decode.rs`).
pub struct BatchDecodeEngine {
    pub model: DecodeModel,
    backend: ParaBackend,
    params: CimParams,
    slots: Vec<BatchSlot>,
    /// Stride-B interleaved staging (op input) buffer, `max(d, d_ff) *
    /// capacity` wide — allocated once, reused every step.
    xb: Vec<f32>,
    /// Stride-B interleaved landing (op output) buffer.
    yb: Vec<f32>,
}

impl BatchDecodeEngine {
    /// Batched engine with the golden (non-CIM) Para backend.
    pub fn reference(model: DecodeModel, capacity: usize) -> BatchDecodeEngine {
        Self::with_backend(model, ParaBackend::Reference, CimParams::default(), capacity)
    }

    /// Batched engine whose Para ops run on an emulated chip programmed
    /// with the given mapping strategy (one chip for all slots — the
    /// weights are resident once, the batch rides for free).
    pub fn on_chip(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
        capacity: usize,
    ) -> BatchDecodeEngine {
        let chip = FunctionalChip::program_rect(
            &model.cfg,
            &model.ops,
            &model.weights,
            &params,
            strategy,
        );
        Self::with_backend(model, ParaBackend::Chip(Box::new(chip)), params, capacity)
    }

    fn with_backend(
        model: DecodeModel,
        backend: ParaBackend,
        params: CimParams,
        capacity: usize,
    ) -> BatchDecodeEngine {
        assert!(capacity >= 1, "need at least one sequence slot");
        let slots: Vec<BatchSlot> =
            (0..capacity).map(|_| BatchSlot::new(&model.cfg)).collect();
        let wide = model.cfg.d_model.max(model.cfg.d_ff);
        BatchDecodeEngine {
            xb: vec![0.0; wide * capacity],
            yb: vec![0.0; wide * capacity],
            model,
            backend,
            params,
            slots,
        }
    }

    /// Total sequence slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Currently occupied slots.
    pub fn occupancy(&self) -> usize {
        self.slots.iter().filter(|s| s.active).count()
    }

    /// Whether `slot` currently holds an in-flight sequence.
    pub fn is_active(&self, slot: usize) -> bool {
        self.slots[slot].active
    }

    /// Claim a free slot for a new sequence (cleared KV/trace/logits);
    /// `None` when every slot is occupied.
    pub fn try_admit(&mut self) -> Option<usize> {
        let s = self.slots.iter().position(|s| !s.active)?;
        let slot = &mut self.slots[s];
        slot.active = true;
        slot.clear();
        Some(s)
    }

    /// Evict a slot (finished or cancelled sequence). All request state
    /// is wiped immediately, so a later occupant can never observe it.
    pub fn release(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        s.active = false;
        s.clear();
    }

    /// Cached positions of one slot.
    pub fn kv_len(&self, slot: usize) -> usize {
        self.slots[slot].kv_len()
    }

    /// LM-head logits of the slot's latest stepped position (borrowed
    /// from the slot's buffer — valid until its next step).
    pub fn logits(&self, slot: usize) -> &[f32] {
        &self.slots[slot].bufs.logits
    }

    /// Move the slot's accumulated per-position costs out (one entry
    /// per stepped position since admission).
    pub fn take_trace(&mut self, slot: usize) -> Vec<Cost> {
        std::mem::take(&mut self.slots[slot].trace.per_token)
    }

    /// The chip's mapping (None for the reference backend).
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        match &self.backend {
            ParaBackend::Chip(c) => Some(&c.mapping),
            ParaBackend::Reference => None,
        }
    }

    /// Advance the listed slots by one token each (`(slot, token)`
    /// pairs; slots must be active and distinct, any subset and order).
    /// Every Para matmul runs once, batched over the lanes; everything
    /// per-sequence (LayerNorm, attention against the slot's own KV
    /// cache, residuals, LM head) runs lane by lane on the slot's
    /// private buffers. Appends K/V to each slot's cache and records a
    /// per-slot cost at the slot's own KV length.
    pub fn step(&mut self, inputs: &[(usize, i32)]) {
        let batch = inputs.len();
        assert!(batch > 0, "step needs at least one active slot");
        let BatchDecodeEngine {
            model,
            backend,
            params,
            slots,
            xb,
            yb,
        } = self;
        let d = model.cfg.d_model;
        let d_ff = model.cfg.d_ff;
        let heads = model.cfg.n_heads;
        let dh = model.cfg.d_head();
        let vocab = model.cfg.vocab;
        let n_layers = model.cfg.dec_layers;
        let lane_slots: Vec<usize> = inputs.iter().map(|&(s, _)| s).collect();
        for (i, &si) in lane_slots.iter().enumerate() {
            assert!(si < slots.len(), "slot {si} out of range");
            assert!(slots[si].active, "step on inactive slot {si}");
            assert!(
                !lane_slots[..i].contains(&si),
                "duplicate slot {si} in one step"
            );
        }

        // token + positional embedding, per lane at the lane's position
        for &(si, token) in inputs {
            let slot = &mut slots[si];
            let pos = slot.kv_len().min(model.cfg.seq - 1);
            let tok = (token.max(0) as usize).min(vocab - 1);
            for ((hv, e), p) in slot
                .bufs
                .h
                .iter_mut()
                .zip(model.embedding.row(tok))
                .zip(model.positional.row(pos))
            {
                *hv = e + p;
            }
        }

        for l in 0..n_layers {
            let ops = model.layers[l];
            // --- self-attention sub-block (pre-LN) ---
            for &si in &lane_slots {
                let b = &mut slots[si].bufs;
                layer_norm_into(&b.h, &mut b.x);
            }
            pack_lanes(&mut xb[..d * batch], d, &slots[..], &lane_slots, buf_x);
            backend.run_batch_into(model, ops.wq, batch, &xb[..d * batch], &mut yb[..d * batch]);
            unpack_lanes(&yb[..d * batch], d, &mut slots[..], &lane_slots, buf_q_mut);
            backend.run_batch_into(model, ops.wk, batch, &xb[..d * batch], &mut yb[..d * batch]);
            unpack_lanes(&yb[..d * batch], d, &mut slots[..], &lane_slots, buf_k_mut);
            backend.run_batch_into(model, ops.wv, batch, &xb[..d * batch], &mut yb[..d * batch]);
            unpack_lanes(&yb[..d * batch], d, &mut slots[..], &lane_slots, buf_v_mut);
            for &si in &lane_slots {
                let slot = &mut slots[si];
                slot.keys[l].push(slot.bufs.k.clone());
                slot.values[l].push(slot.bufs.v.clone());
                attend_into(
                    &slot.bufs.q,
                    &slot.keys[l],
                    &slot.values[l],
                    heads,
                    dh,
                    &mut slot.bufs.scores,
                    &mut slot.bufs.ctx,
                );
            }
            pack_lanes(&mut xb[..d * batch], d, &slots[..], &lane_slots, buf_ctx);
            backend.run_batch_into(model, ops.wo, batch, &xb[..d * batch], &mut yb[..d * batch]);
            unpack_lanes(&yb[..d * batch], d, &mut slots[..], &lane_slots, buf_o_mut);
            // --- feed-forward sub-block (pre-LN) ---
            for &si in &lane_slots {
                let b = &mut slots[si].bufs;
                for (hv, ov) in b.h.iter_mut().zip(&b.o) {
                    *hv += ov;
                }
                layer_norm_into(&b.h, &mut b.x);
            }
            pack_lanes(&mut xb[..d * batch], d, &slots[..], &lane_slots, buf_x);
            backend.run_batch_into(
                model,
                ops.ffn1,
                batch,
                &xb[..d * batch],
                &mut yb[..d_ff * batch],
            );
            unpack_lanes(&yb[..d_ff * batch], d_ff, &mut slots[..], &lane_slots, buf_f_mut);
            for &si in &lane_slots {
                gelu(&mut slots[si].bufs.f);
            }
            pack_lanes(&mut xb[..d_ff * batch], d_ff, &slots[..], &lane_slots, buf_f);
            backend.run_batch_into(
                model,
                ops.ffn2,
                batch,
                &xb[..d_ff * batch],
                &mut yb[..d * batch],
            );
            unpack_lanes(&yb[..d * batch], d, &mut slots[..], &lane_slots, buf_g_mut);
            for &si in &lane_slots {
                let b = &mut slots[si].bufs;
                for (hv, gv) in b.h.iter_mut().zip(&b.g) {
                    *hv += gv;
                }
            }
        }

        // untied LM head over the final LayerNorm + per-slot cost record
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for &si in &lane_slots {
            let slot = &mut slots[si];
            layer_norm_into(&slot.bufs.h, &mut slot.bufs.hn);
            for (t, lv) in slot.bufs.logits.iter_mut().enumerate() {
                let row = model.lm_head.row(t);
                let mut acc = 0.0f32;
                for (r, x) in row.iter().zip(&slot.bufs.hn) {
                    acc += r * x;
                }
                *lv = acc * inv_sqrt_d;
            }
            let kv_len = slot.kv_len();
            let cost = match backend {
                ParaBackend::Chip(chip) => {
                    decode_token_cost(&model.cfg, &chip.mapping, params, kv_len)
                }
                ParaBackend::Reference => Cost::default(),
            };
            slot.trace.record(cost);
        }
    }

    /// Greedy generation of a whole request list through the slot pool
    /// with continuous batching: requests are admitted into free slots
    /// as they open up (more requests than slots exercises mid-run
    /// admission), each slot feeds its prompt then argmax-extends for
    /// `n_tokens`, and finished slots are evicted — and refilled —
    /// without stalling in-flight neighbours. Per request the semantics
    /// (and, bit for bit, the tokens) equal
    /// [`DecodeEngine::generate`] on a fresh single-stream engine.
    pub fn generate_batch(
        &mut self,
        prompts: &[Vec<i32>],
        n_tokens: usize,
    ) -> Vec<DecodeResult> {
        for p in prompts {
            assert!(!p.is_empty(), "need at least one prompt token");
        }
        let cap = self.slots.len();
        // start clean: evict anything left over from a previous run
        for s in 0..cap {
            if self.slots[s].active {
                self.release(s);
            }
        }
        let mut results: Vec<DecodeResult> = prompts
            .iter()
            .map(|_| DecodeResult {
                tokens: Vec::with_capacity(n_tokens),
                per_token: Vec::new(),
            })
            .collect();
        // per-slot (request index, forwards done so far)
        let mut running: Vec<Option<(usize, usize)>> = vec![None; cap];
        let mut next_req = 0usize;
        let mut inputs: Vec<(usize, i32)> = Vec::with_capacity(cap);
        loop {
            while next_req < prompts.len() {
                match self.try_admit() {
                    Some(s) => {
                        running[s] = Some((next_req, 0));
                        next_req += 1;
                    }
                    None => break,
                }
            }
            inputs.clear();
            for (s, run) in running.iter().enumerate() {
                if let Some((req, fed)) = *run {
                    let tok = if fed < prompts[req].len() {
                        prompts[req][fed]
                    } else {
                        // argmax over the slot's last logits — exactly
                        // DecodeEngine::generate's continuation rule
                        let t = argmax(self.logits(s)) as i32;
                        results[req].tokens.push(t);
                        t
                    };
                    inputs.push((s, tok));
                }
            }
            if inputs.is_empty() {
                break;
            }
            self.step(&inputs);
            for &(s, _) in inputs.iter() {
                let (req, fed) = running[s].expect("stepped slot is running");
                let done = fed + 1;
                if done == prompts[req].len() + n_tokens {
                    results[req].per_token = self.take_trace(s);
                    self.release(s);
                    running[s] = None;
                } else {
                    running[s] = Some((req, done));
                }
            }
        }
        results
    }
}

/// Digital multi-head attention of one query against the KV cache, into
/// caller-owned context/score scratch (every entry overwritten).
fn attend_into(
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
    heads: usize,
    dh: usize,
    scores: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    let t = keys.len();
    let scale = 1.0 / (dh as f32).sqrt();
    ctx.fill(0.0);
    scores.resize(t, 0.0);
    for h in 0..heads {
        let o = h * dh;
        for (i, k) in keys.iter().enumerate() {
            let mut s = 0.0f32;
            for j in 0..dh {
                s += q[o + j] * k[o + j];
            }
            scores[i] = s * scale;
        }
        let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        for (i, v) in values.iter().enumerate() {
            let a = scores[i] * inv;
            for j in 0..dh {
                ctx[o + j] += a * v[o + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn model_synthesis_is_deterministic() {
        let a = DecodeModel::synth(tiny(), 7);
        let b = DecodeModel::synth(tiny(), 7);
        assert_eq!(a.weights.len(), b.weights.len());
        for (wa, wb) in a.weights.iter().zip(&b.weights) {
            for (ta, tb) in wa.tiles.iter().zip(&wb.tiles) {
                assert_eq!(ta.l.data, tb.l.data);
                assert_eq!(ta.r.data, tb.r.data);
            }
        }
        assert_eq!(a.embedding.data, b.embedding.data);
        let c = DecodeModel::synth(tiny(), 8);
        assert_ne!(a.embedding.data, c.embedding.data);
    }

    #[test]
    fn reference_engine_generates_and_caches() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 3));
        let r = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens.len(), 8);
        assert_eq!(eng.kv_len(), 3 + 8);
        let vocab = tiny().vocab as i32;
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
        // regeneration from the same prompt is identical
        let r2 = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens, r2.tokens);
    }

    #[test]
    fn kv_cache_matches_full_recompute() {
        // Scoring [t0..t3] incrementally must give the same final-position
        // logits as re-running the prefix from scratch.
        let model = DecodeModel::synth(tiny(), 11);
        let mut eng = DecodeEngine::reference(model);
        let toks = [5i32, 9, 2, 40];
        let (all, _) = eng.score(&toks);
        let vocab = tiny().vocab;
        let last = &all[3 * vocab..4 * vocab];
        // recompute: fresh engine, same sequence
        let mut eng2 = DecodeEngine::reference(DecodeModel::synth(tiny(), 11));
        let mut logits = Vec::new();
        for &t in &toks {
            logits = eng2.forward(t).to_vec();
        }
        assert_eq!(last, logits.as_slice());
    }

    #[test]
    fn chip_engine_records_costs_reference_does_not() {
        let params = CimParams::default();
        let model = DecodeModel::synth(tiny(), 5);
        let mut chip = DecodeEngine::on_chip(model, params, Strategy::SparseMap);
        let r = chip.generate(&[1, 2], 4);
        assert_eq!(r.per_token.len(), 6); // 2 prompt + 4 generated
        assert!(r.per_token.iter().all(|c| c.latency.critical_ns() > 0.0));
        // MHA share grows with the cache
        assert!(
            r.per_token.last().unwrap().latency.mha_ns
                > r.per_token.first().unwrap().latency.mha_ns
        );
        // the result owns the run's trace (moved, not copied)
        assert_eq!(chip.trace.tokens(), 0);
        let mut reference = DecodeEngine::reference(DecodeModel::synth(tiny(), 5));
        let rr = reference.generate(&[1, 2], 4);
        assert!(rr.per_token.iter().all(|c| c.latency.critical_ns() == 0.0));
        assert!(chip.mapping().is_some());
        assert!(reference.mapping().is_none());
    }

    #[test]
    fn engine_reuse_equals_fresh_engine() {
        // Slot-reuse regression (ISSUE 3): generating on a dirtied
        // engine must equal a fresh engine token-for-token — reset
        // leaves no KV, trace, score-window or logit residue behind.
        let params = CimParams::default();
        for strategy in [Strategy::SparseMap, Strategy::DenseMap] {
            let mut used = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 21),
                params.clone(),
                strategy,
            );
            let _ = used.generate(&[9, 1, 7, 13], 6); // dirty KV/trace/logits
            let reused = used.generate(&[3, 4], 6);
            let mut fresh = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 21),
                params.clone(),
                strategy,
            );
            let direct = fresh.generate(&[3, 4], 6);
            assert_eq!(reused.tokens, direct.tokens, "{strategy:?}: reuse drifted");
            assert_eq!(reused.per_token.len(), direct.per_token.len());
        }
    }

    #[test]
    fn batch_step_logits_match_single_forward_bitwise() {
        // Teacher-forced: two ragged slots stepped together produce, at
        // every position, exactly the single-stream forward's logits.
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 3), 2);
        let s0 = be.try_admit().unwrap();
        let s1 = be.try_admit().unwrap();
        assert!(be.try_admit().is_none(), "capacity 2 means 2 slots");
        let seqs = [vec![5i32, 9, 2], vec![8i32, 1, 30]];
        let mut singles = [
            DecodeEngine::reference(DecodeModel::synth(tiny(), 3)),
            DecodeEngine::reference(DecodeModel::synth(tiny(), 3)),
        ];
        for t in 0..3 {
            be.step(&[(s0, seqs[0][t]), (s1, seqs[1][t])]);
            for (i, &s) in [s0, s1].iter().enumerate() {
                let want = singles[i].forward(seqs[i][t]).to_vec();
                assert_eq!(be.logits(s), want.as_slice(), "slot {i} pos {t}");
            }
        }
        // evict slot 0; the freed slot readmits clean while slot 1 keeps
        // its cache (ragged coexistence)
        be.release(s0);
        assert_eq!(be.occupancy(), 1);
        let s2 = be.try_admit().unwrap();
        assert_eq!(s2, s0, "freed slot is reusable");
        assert_eq!(be.kv_len(s2), 0, "readmitted slot starts empty");
        assert_eq!(be.kv_len(s1), 3, "neighbour cache untouched");
    }

    #[test]
    fn generate_batch_matches_single_stream_engines() {
        let params = CimParams::default();
        let mut be = BatchDecodeEngine::on_chip(
            DecodeModel::synth(tiny(), 5),
            params.clone(),
            Strategy::DenseMap,
            3,
        );
        let prompts = vec![vec![1, 2, 3], vec![7, 8], vec![40, 41, 42, 43]];
        let results = be.generate_batch(&prompts, 5);
        for (p, r) in prompts.iter().zip(&results) {
            let mut single = DecodeEngine::on_chip(
                DecodeModel::synth(tiny(), 5),
                params.clone(),
                Strategy::DenseMap,
            );
            let want = single.generate(p, 5);
            assert_eq!(r.tokens, want.tokens, "prompt {p:?}");
            assert_eq!(r.per_token.len(), want.per_token.len());
        }
    }

    #[test]
    fn generate_batch_admits_beyond_capacity() {
        // 5 requests through 2 slots: finished slots are evicted and
        // refilled mid-run without disturbing in-flight neighbours.
        let mut be = BatchDecodeEngine::reference(DecodeModel::synth(tiny(), 9), 2);
        let prompts: Vec<Vec<i32>> = (0..5)
            .map(|i| (0..(i % 3 + 1)).map(|j| (i * 13 + j * 7 + 1) as i32).collect())
            .collect();
        let results = be.generate_batch(&prompts, 4);
        assert_eq!(results.len(), 5);
        assert_eq!(be.occupancy(), 0, "all slots evicted at end");
        assert!(results.iter().all(|r| r.tokens.len() == 4));
        for (p, r) in prompts.iter().zip(&results) {
            let mut single = DecodeEngine::reference(DecodeModel::synth(tiny(), 9));
            assert_eq!(r.tokens, single.generate(p, 4).tokens, "prompt {p:?}");
        }
    }

    #[test]
    fn score_is_reset_safe() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        let toks = vec![7i32; tiny().seq];
        let (a, _) = eng.score(&toks);
        let (b, _) = eng.score(&toks);
        assert_eq!(a, b, "score must be independent of prior requests");
        assert_eq!(a.len(), tiny().seq * tiny().vocab);
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
