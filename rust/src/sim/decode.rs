//! Autoregressive decode engine: a full decoder-only transformer forward
//! pass, token by token with a growing KV cache, whose *parameterized*
//! matmuls run on the emulated crossbar chip ([`FunctionalChip`]) under
//! any of the three mapping strategies — the workload the paper actually
//! measures (Fig. 7/8's token-streaming decode regime), not an isolated
//! matvec.
//!
//! Split of responsibilities (paper Fig. 2b):
//! * **Para ops** (`wq/wk/wv/wo/ffn1/ffn2`) — weight-stationary in CIM
//!   arrays; executed by `FunctionalChip::run_op_into` replaying the
//!   compiled plan (`scheduler::plan`) with scheduler-issued
//!   row-activation masks, pre-rotated column conversion and stride
//!   permutations.
//! * **NonPara ops** (attention scores `qk` and context `av`) — digital,
//!   on the MHA unit: computed here in f32 against the KV cache; their
//!   cost is `trace::mha_token_cost` (grows with the cache).
//! * Everything else (LayerNorm, GeLU, residuals, embedding/LM head) —
//!   DPU vector ops, identical across backends.
//!
//! The steady-state token loop is allocation-free: the engine owns one
//! [`EngineBufs`] set of activation buffers (reused every token, every
//! request), the chip owns its pass scratch, and the only per-token heap
//! traffic is the KV-cache append — state, not scratch.
//!
//! Because the chip's Monarch passes replay the factored reference's f32
//! operations in the same order, SparseMap/DenseMap decode is
//! bit-identical to the [`RectMonarch`] reference model; Linear programs
//! the *dense materialization* of the same operator and agrees to float
//! tolerance — so greedy token sequences match across all three
//! strategies (tier-1 `tests/integration_decode.rs`).

use std::collections::HashMap;

use crate::cim::{CimParams, Cost};
use crate::mapping::Strategy;
use crate::model::{para_ops, MatmulOp, ModelConfig};
use crate::monarch::{MonarchMatrix, RectMonarch};
use crate::sim::exec::FunctionalChip;
use crate::sim::trace::{decode_token_cost, DecodeTrace};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

/// Parameterized-op indices of one decoder layer (into the para-op list).
#[derive(Clone, Copy, Debug)]
struct LayerOps {
    wq: usize,
    wk: usize,
    wv: usize,
    wo: usize,
    ffn1: usize,
    ffn2: usize,
}

/// A synthetic Monarch decoder-only transformer: every Para weight is a
/// tile grid of Monarch factors (deterministically seeded), plus token
/// embeddings, learned positional embeddings and an untied LM head (a
/// tied head makes a random-weight model echo its input token forever —
/// untied gives non-degenerate greedy sequences, with comfortable
/// argmax margins, ~0.01 at the tiny config).
pub struct DecodeModel {
    pub cfg: ModelConfig,
    pub ops: Vec<MatmulOp>,
    pub weights: Vec<RectMonarch>,
    /// Token embedding table (vocab x d).
    pub embedding: Matrix,
    /// Learned positional embeddings (seq x d).
    pub positional: Matrix,
    /// Untied LM head (vocab x d).
    pub lm_head: Matrix,
    layers: Vec<LayerOps>,
}

/// Variance-preserving random Monarch tile (factors scaled by 1/sqrt(b)).
fn scaled_monarch(b: usize, rng: &mut Pcg32) -> MonarchMatrix {
    let mut m = MonarchMatrix::randn(b, rng);
    let s = 1.0 / (b as f32).sqrt();
    for v in m.l.data.iter_mut() {
        *v *= s;
    }
    for v in m.r.data.iter_mut() {
        *v *= s;
    }
    m
}

impl DecodeModel {
    /// Deterministically synthesize weights for a decoder-only config.
    /// Takes the config by value — callers that keep one pass a clone,
    /// everyone else just moves it in.
    pub fn synth(cfg: ModelConfig, seed: u64) -> DecodeModel {
        assert_eq!(
            cfg.enc_layers, 0,
            "decode engine targets decoder-only models (got {})",
            cfg.name
        );
        assert!(cfg.dec_layers > 0, "model has no decoder layers");
        let d = cfg.d_model;
        let b = cfg.monarch_b();
        let ops = para_ops(&cfg);
        let weights: Vec<RectMonarch> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                let mut rng = Pcg32::stream(seed, i as u64);
                let tiles = op.rows.div_ceil(d) * op.cols.div_ceil(d);
                RectMonarch {
                    rows: op.rows,
                    cols: op.cols,
                    n: d,
                    tiles: (0..tiles).map(|_| scaled_monarch(b, &mut rng)).collect(),
                }
            })
            .collect();
        let by_name: HashMap<&str, usize> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| (op.name.as_str(), i))
            .collect();
        let layers = (0..cfg.dec_layers)
            .map(|l| {
                let idx = |w: &str| -> usize {
                    *by_name
                        .get(format!("dec{l}.{w}").as_str())
                        .unwrap_or_else(|| panic!("missing op dec{l}.{w}"))
                };
                LayerOps {
                    wq: idx("wq"),
                    wk: idx("wk"),
                    wv: idx("wv"),
                    wo: idx("wo"),
                    ffn1: idx("ffn1"),
                    ffn2: idx("ffn2"),
                }
            })
            .collect();
        let embedding = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0x5eed));
        let positional =
            Matrix::randn(cfg.seq, d, &mut Pcg32::stream(seed, 0x905e)).scale(0.1);
        let lm_head = Matrix::randn(cfg.vocab, d, &mut Pcg32::stream(seed, 0xeadd));
        DecodeModel {
            cfg,
            ops,
            weights,
            embedding,
            positional,
            lm_head,
            layers,
        }
    }

    /// Reference Para matmul (`y = W x`) through the factored tiles.
    pub fn reference_matvec(&self, op_idx: usize, x: &[f32]) -> Vec<f32> {
        self.weights[op_idx].matvec(x)
    }
}

/// Where the Para matmuls execute.
pub enum ParaBackend {
    /// Plain `RectMonarch::matvec` — the golden model.
    Reference,
    /// Emulated crossbar chip programmed under one mapping strategy.
    Chip(Box<FunctionalChip>),
}

impl ParaBackend {
    /// Execute `y = W x` for op `op_idx` into a caller buffer. The chip
    /// path replays the compiled plan allocation-free; the reference
    /// path runs the golden factored matvec.
    fn run_into(&mut self, model: &DecodeModel, op_idx: usize, x: &[f32], y: &mut [f32]) {
        match self {
            ParaBackend::Reference => {
                let r = model.reference_matvec(op_idx, x);
                y.copy_from_slice(&r);
            }
            ParaBackend::Chip(chip) => chip.run_op_into(op_idx, x, y),
        }
    }
}

/// Per-token activation buffers, allocated once per engine and reused
/// across tokens and requests (the serving worker keeps one engine, so
/// this scratch also persists across requests).
struct EngineBufs {
    /// Residual stream (d).
    h: Vec<f32>,
    /// LayerNorm output feeding the current sub-block (d).
    x: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention context (d).
    ctx: Vec<f32>,
    o: Vec<f32>,
    /// FFN hidden (d_ff).
    f: Vec<f32>,
    g: Vec<f32>,
    /// Final LayerNorm output (d).
    hn: Vec<f32>,
    /// Attention score scratch (grows to the KV length; capacity
    /// reserved for the model's context window).
    scores: Vec<f32>,
    /// LM-head logits of the latest forwarded position (vocab).
    logits: Vec<f32>,
}

impl EngineBufs {
    fn new(cfg: &ModelConfig) -> Self {
        let d = cfg.d_model;
        Self {
            h: vec![0.0; d],
            x: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            ctx: vec![0.0; d],
            o: vec![0.0; d],
            f: vec![0.0; cfg.d_ff],
            g: vec![0.0; d],
            hn: vec![0.0; d],
            scores: Vec::with_capacity(cfg.seq),
            logits: vec![0.0; cfg.vocab],
        }
    }
}

/// The decode engine: owns the model, the Para backend, the KV cache and
/// the per-token scratch; generates tokens greedily and accounts
/// latency/energy per token.
pub struct DecodeEngine {
    pub model: DecodeModel,
    backend: ParaBackend,
    params: CimParams,
    /// Per-layer key/value cache (one d-vector per cached position).
    keys: Vec<Vec<Vec<f32>>>,
    values: Vec<Vec<Vec<f32>>>,
    pub trace: DecodeTrace,
    bufs: EngineBufs,
}

/// Result of one greedy generation run. The per-token costs are *moved*
/// out of the engine's trace (no deep copy): after `generate` returns,
/// the engine's own trace is empty until the next run records into it.
#[derive(Clone, Debug)]
pub struct DecodeResult {
    /// The generated token ids (prompt excluded).
    pub tokens: Vec<i32>,
    /// Modeled cost of every processed position (prompt + generated).
    pub per_token: Vec<Cost>,
}

impl DecodeResult {
    /// Summed modeled cost of the whole run (the counterpart of
    /// `DecodeTrace::total` for the moved-out per-token records).
    pub fn total(&self) -> Cost {
        crate::sim::trace::sum_costs(&self.per_token)
    }
}

fn layer_norm_into(x: &[f32], out: &mut [f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for (o, v) in out.iter_mut().zip(x) {
        *o = (v - mean) * inv;
    }
}

fn gelu(x: &mut [f32]) {
    // tanh approximation (identical across backends; DPU op)
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for v in x.iter_mut() {
        let u = *v;
        *v = 0.5 * u * (1.0 + (C * (u + 0.044_715 * u * u * u)).tanh());
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

impl DecodeEngine {
    /// Engine with the golden (non-CIM) Para backend.
    pub fn reference(model: DecodeModel) -> DecodeEngine {
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            model,
            backend: ParaBackend::Reference,
            params: CimParams::default(),
            keys: vec![Vec::new(); layers],
            values: vec![Vec::new(); layers],
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// Engine whose Para ops run on an emulated chip programmed with the
    /// given mapping strategy. Takes the CIM parameters by value (the
    /// engine stores them for per-token cost accounting).
    pub fn on_chip(
        model: DecodeModel,
        params: CimParams,
        strategy: Strategy,
    ) -> DecodeEngine {
        let chip = FunctionalChip::program_rect(
            &model.cfg,
            &model.ops,
            &model.weights,
            &params,
            strategy,
        );
        let layers = model.cfg.dec_layers;
        let bufs = EngineBufs::new(&model.cfg);
        DecodeEngine {
            model,
            backend: ParaBackend::Chip(Box::new(chip)),
            params,
            keys: vec![Vec::new(); layers],
            values: vec![Vec::new(); layers],
            trace: DecodeTrace::new(),
            bufs,
        }
    }

    /// The chip's mapping (None for the reference backend).
    pub fn mapping(&self) -> Option<&crate::mapping::ModelMapping> {
        match &self.backend {
            ParaBackend::Chip(c) => Some(&c.mapping),
            ParaBackend::Reference => None,
        }
    }

    /// Clear the KV cache and the trace (new sequence).
    pub fn reset(&mut self) {
        for k in self.keys.iter_mut() {
            k.clear();
        }
        for v in self.values.iter_mut() {
            v.clear();
        }
        self.trace.clear();
    }

    /// Cached positions so far.
    pub fn kv_len(&self) -> usize {
        self.keys.first().map(|k| k.len()).unwrap_or(0)
    }

    /// Process one token at the next position; returns the LM-head
    /// logits (borrowed from the engine's reusable logit buffer — copy
    /// them out if they must outlive the next forward). Appends K/V to
    /// the cache and records the position's cost.
    pub fn forward(&mut self, token: i32) -> &[f32] {
        let pos = self.kv_len().min(self.model.cfg.seq - 1);
        let DecodeEngine {
            model,
            backend,
            params,
            keys,
            values,
            trace,
            bufs,
        } = self;
        let d = model.cfg.d_model;
        let heads = model.cfg.n_heads;
        let dh = model.cfg.d_head();
        let vocab = model.cfg.vocab;
        let n_layers = model.cfg.dec_layers;
        let tok = (token.max(0) as usize).min(vocab - 1);

        for ((hv, e), p) in bufs
            .h
            .iter_mut()
            .zip(model.embedding.row(tok))
            .zip(model.positional.row(pos))
        {
            *hv = e + p;
        }

        for l in 0..n_layers {
            let ops = model.layers[l];
            // --- self-attention sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.wq, &bufs.x, &mut bufs.q);
            backend.run_into(model, ops.wk, &bufs.x, &mut bufs.k);
            backend.run_into(model, ops.wv, &bufs.x, &mut bufs.v);
            keys[l].push(bufs.k.clone());
            values[l].push(bufs.v.clone());
            attend_into(
                &bufs.q,
                &keys[l],
                &values[l],
                heads,
                dh,
                &mut bufs.scores,
                &mut bufs.ctx,
            );
            backend.run_into(model, ops.wo, &bufs.ctx, &mut bufs.o);
            for (hv, ov) in bufs.h.iter_mut().zip(&bufs.o) {
                *hv += ov;
            }
            // --- feed-forward sub-block (pre-LN) ---
            layer_norm_into(&bufs.h, &mut bufs.x);
            backend.run_into(model, ops.ffn1, &bufs.x, &mut bufs.f);
            gelu(&mut bufs.f);
            backend.run_into(model, ops.ffn2, &bufs.f, &mut bufs.g);
            for (hv, gv) in bufs.h.iter_mut().zip(&bufs.g) {
                *hv += gv;
            }
        }

        // untied LM head over the final LayerNorm
        layer_norm_into(&bufs.h, &mut bufs.hn);
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();
        for (t, lv) in bufs.logits.iter_mut().enumerate() {
            let row = model.lm_head.row(t);
            let mut acc = 0.0f32;
            for (r, x) in row.iter().zip(&bufs.hn) {
                acc += r * x;
            }
            *lv = acc * inv_sqrt_d;
        }

        // cost accounting: the mapped Para path + cache-sized MHA work
        let kv_len = keys.first().map(|k| k.len()).unwrap_or(0);
        let cost = match backend {
            ParaBackend::Chip(chip) => {
                decode_token_cost(&model.cfg, &chip.mapping, params, kv_len)
            }
            ParaBackend::Reference => Cost::default(),
        };
        trace.record(cost);
        &bufs.logits[..]
    }

    /// Greedy autoregressive generation: feed `prompt`, then emit
    /// `n_tokens` argmax continuations. The engine is reset first.
    pub fn generate(&mut self, prompt: &[i32], n_tokens: usize) -> DecodeResult {
        assert!(!prompt.is_empty(), "need at least one prompt token");
        self.reset();
        for &t in prompt {
            self.forward(t);
        }
        let mut tokens = Vec::with_capacity(n_tokens);
        for _ in 0..n_tokens {
            let next = argmax(&self.bufs.logits) as i32;
            tokens.push(next);
            self.forward(next);
        }
        DecodeResult {
            tokens,
            per_token: std::mem::take(&mut self.trace.per_token),
        }
    }

    /// Teacher-forced scoring: per-position logits (`seq * vocab`) for a
    /// full token window, plus the summed modeled cost — the CIM-sim
    /// serving contract (`coordinator::server::Backend::CimSim`).
    pub fn score(&mut self, tokens: &[i32]) -> (Vec<f32>, Cost) {
        self.reset();
        let vocab = self.model.cfg.vocab;
        let mut out = Vec::with_capacity(tokens.len() * vocab);
        for &t in tokens {
            let logits = self.forward(t);
            out.extend_from_slice(logits);
        }
        (out, self.trace.total())
    }
}

/// Digital multi-head attention of one query against the KV cache, into
/// caller-owned context/score scratch (every entry overwritten).
fn attend_into(
    q: &[f32],
    keys: &[Vec<f32>],
    values: &[Vec<f32>],
    heads: usize,
    dh: usize,
    scores: &mut Vec<f32>,
    ctx: &mut [f32],
) {
    let t = keys.len();
    let scale = 1.0 / (dh as f32).sqrt();
    ctx.fill(0.0);
    scores.resize(t, 0.0);
    for h in 0..heads {
        let o = h * dh;
        for (i, k) in keys.iter().enumerate() {
            let mut s = 0.0f32;
            for j in 0..dh {
                s += q[o + j] * k[o + j];
            }
            scores[i] = s * scale;
        }
        let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            z += *s;
        }
        let inv = 1.0 / z;
        for (i, v) in values.iter().enumerate() {
            let a = scores[i] * inv;
            for j in 0..dh {
                ctx[o + j] += a * v[o + j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn model_synthesis_is_deterministic() {
        let a = DecodeModel::synth(tiny(), 7);
        let b = DecodeModel::synth(tiny(), 7);
        assert_eq!(a.weights.len(), b.weights.len());
        for (wa, wb) in a.weights.iter().zip(&b.weights) {
            for (ta, tb) in wa.tiles.iter().zip(&wb.tiles) {
                assert_eq!(ta.l.data, tb.l.data);
                assert_eq!(ta.r.data, tb.r.data);
            }
        }
        assert_eq!(a.embedding.data, b.embedding.data);
        let c = DecodeModel::synth(tiny(), 8);
        assert_ne!(a.embedding.data, c.embedding.data);
    }

    #[test]
    fn reference_engine_generates_and_caches() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 3));
        let r = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens.len(), 8);
        assert_eq!(eng.kv_len(), 3 + 8);
        let vocab = tiny().vocab as i32;
        assert!(r.tokens.iter().all(|&t| t >= 0 && t < vocab));
        // regeneration from the same prompt is identical
        let r2 = eng.generate(&[1, 2, 3], 8);
        assert_eq!(r.tokens, r2.tokens);
    }

    #[test]
    fn kv_cache_matches_full_recompute() {
        // Scoring [t0..t3] incrementally must give the same final-position
        // logits as re-running the prefix from scratch.
        let model = DecodeModel::synth(tiny(), 11);
        let mut eng = DecodeEngine::reference(model);
        let toks = [5i32, 9, 2, 40];
        let (all, _) = eng.score(&toks);
        let vocab = tiny().vocab;
        let last = &all[3 * vocab..4 * vocab];
        // recompute: fresh engine, same sequence
        let mut eng2 = DecodeEngine::reference(DecodeModel::synth(tiny(), 11));
        let mut logits = Vec::new();
        for &t in &toks {
            logits = eng2.forward(t).to_vec();
        }
        assert_eq!(last, logits.as_slice());
    }

    #[test]
    fn chip_engine_records_costs_reference_does_not() {
        let params = CimParams::default();
        let model = DecodeModel::synth(tiny(), 5);
        let mut chip = DecodeEngine::on_chip(model, params, Strategy::SparseMap);
        let r = chip.generate(&[1, 2], 4);
        assert_eq!(r.per_token.len(), 6); // 2 prompt + 4 generated
        assert!(r.per_token.iter().all(|c| c.latency.critical_ns() > 0.0));
        // MHA share grows with the cache
        assert!(
            r.per_token.last().unwrap().latency.mha_ns
                > r.per_token.first().unwrap().latency.mha_ns
        );
        // the result owns the run's trace (moved, not copied)
        assert_eq!(chip.trace.tokens(), 0);
        let mut reference = DecodeEngine::reference(DecodeModel::synth(tiny(), 5));
        let rr = reference.generate(&[1, 2], 4);
        assert!(rr.per_token.iter().all(|c| c.latency.critical_ns() == 0.0));
        assert!(chip.mapping().is_some());
        assert!(reference.mapping().is_none());
    }

    #[test]
    fn score_is_reset_safe() {
        let mut eng = DecodeEngine::reference(DecodeModel::synth(tiny(), 13));
        let toks = vec![7i32; tiny().seq];
        let (a, _) = eng.score(&toks);
        let (b, _) = eng.score(&toks);
        assert_eq!(a, b, "score must be independent of prior requests");
        assert_eq!(a.len(), tiny().seq * tiny().vocab);
        assert!(a.iter().all(|v| v.is_finite()));
    }
}
