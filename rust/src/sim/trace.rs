//! Execution trace: a timestamped record of scheduler events for one
//! token pass, exportable as JSON (for external timeline visualisation)
//! and queryable for per-resource occupancy — the observability layer of
//! the simulator. [`DecodeTrace`] extends it to autoregressive decode:
//! per-token latency/energy with the growing-KV-cache attention cost.

use crate::cim::{CimParams, Cost, Energy, Latency};
use crate::mapping::{ModelMapping, Strategy};
use crate::model::ModelConfig;
use crate::scheduler::{adc_bits_for, usable_adcs};
use crate::util::json::{arr, num, obj, s, Json};

/// One traced scheduler event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub t_start_ns: f64,
    pub t_end_ns: f64,
    /// `analog` | `convert` | `comm` | `dpu`
    pub kind: &'static str,
    pub op: String,
    pub layer: usize,
    /// Arrays occupied by the event.
    pub arrays: Vec<usize>,
}

/// A full per-token trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Build the slot-model trace of one token pass over a mapping.
    pub fn of_token(
        cfg: &ModelConfig,
        mapping: &ModelMapping,
        params: &CimParams,
    ) -> Trace {
        let mut t = 0.0f64;
        let mut events = Vec::new();
        let bits = adc_bits_for(params, mapping.strategy, mapping.b);
        let adcs = usable_adcs(params, mapping.strategy, mapping.b);
        let t_conv = crate::cim::adc::t_conversion_ns(params, bits);
        let layers: std::collections::BTreeSet<usize> =
            mapping.ops.iter().map(|o| o.layer).collect();
        for layer in layers {
            // group ops of this layer by slot order (same as the timing
            // model: qkv | wo | ffn1 | ffn2)
            let slot_of = |name: &str| -> usize {
                if name.ends_with(".wq") || name.ends_with(".wk") || name.ends_with(".wv") {
                    0
                } else if name.ends_with(".wo") {
                    1
                } else if name.ends_with(".ffn1") {
                    2
                } else {
                    3
                }
            };
            let mut slots: Vec<Vec<usize>> = vec![Vec::new(); 4];
            for (i, op) in mapping.ops.iter().enumerate() {
                if op.layer == layer {
                    slots[slot_of(&op.name)].push(i);
                }
            }
            for slot in slots.iter().filter(|sl| !sl.is_empty()) {
                let mut slot_end = t;
                for &oi in slot {
                    let op = &mapping.ops[oi];
                    let drive = params.t_drive_ns()
                        * if mapping.strategy == Strategy::DenseMap {
                            2.0 * op.analog_phases as f64
                        } else {
                            1.0
                        };
                    let conv = (op.convs_per_array as f64 / adcs as f64).ceil()
                        * t_conv
                        * if mapping.strategy == Strategy::DenseMap {
                            (1.0 + crate::scheduler::timing::DENSE_STAGE_SERIALIZATION)
                                * op.analog_phases as f64
                        } else {
                            1.0
                        };
                    events.push(TraceEvent {
                        t_start_ns: t,
                        t_end_ns: t + drive,
                        kind: "analog",
                        op: op.name.clone(),
                        layer,
                        arrays: op.arrays.clone(),
                    });
                    events.push(TraceEvent {
                        t_start_ns: t + drive,
                        t_end_ns: t + drive + conv,
                        kind: "convert",
                        op: op.name.clone(),
                        layer,
                        arrays: op.arrays.clone(),
                    });
                    slot_end = slot_end.max(t + drive + conv);
                }
                t = slot_end;
            }
        }
        let _ = cfg;
        Trace { events }
    }

    /// Makespan of the trace (ns).
    pub fn makespan_ns(&self) -> f64 {
        self.events.iter().fold(0.0, |m, e| m.max(e.t_end_ns))
    }

    /// Busy time of one array (ns).
    pub fn array_busy_ns(&self, array: usize) -> f64 {
        self.events
            .iter()
            .filter(|e| e.arrays.contains(&array))
            .map(|e| e.t_end_ns - e.t_start_ns)
            .sum()
    }

    /// JSON export (chrome-tracing-like flat list).
    pub fn to_json(&self) -> Json {
        arr(self.events.iter().map(|e| {
            obj(vec![
                ("ts", num(e.t_start_ns)),
                ("dur", num(e.t_end_ns - e.t_start_ns)),
                ("kind", s(e.kind)),
                ("op", s(&e.op)),
                ("layer", num(e.layer as f64)),
                ("arrays", num(e.arrays.len() as f64)),
            ])
        }))
    }
}

/// NonPara attention cost of one decode step at a given KV-cache length:
/// per layer, the digital MHA unit performs one `q · K^T` sweep and one
/// `A · V` accumulation over the cache — two vector events per cached
/// position at Table-I `Add` granularity. This is the component that
/// *grows* with the token position (the memory-bound decode regime the
/// paper motivates); the parameterized-matmul cost stays constant.
pub fn mha_token_cost(cfg: &ModelConfig, params: &CimParams, kv_len: usize) -> Cost {
    mha_layers_cost(params, kv_len, cfg.total_layers())
}

/// [`mha_token_cost`] restricted to an explicit layer count — the
/// per-stage share of the MHA bill when layers are sharded across chips
/// (`sim::shard`). Summed over a partition of the model's layers this
/// reproduces the whole-model cost exactly.
pub fn mha_layers_cost(params: &CimParams, kv_len: usize, layers: usize) -> Cost {
    let layers = layers.max(1) as f64;
    let events = 2.0 * kv_len as f64 * layers;
    Cost {
        latency: Latency {
            mha_ns: events * params.t_add_ns,
            ..Default::default()
        },
        energy: Energy {
            mha_nj: events * params.e_add_nj,
            ..Default::default()
        },
    }
}

/// Full cost of decoding one token at KV length `kv_len`: the mapped
/// parameterized-matmul path (`scheduler::timing::per_token_cost`) plus
/// the cache-proportional MHA work.
pub fn decode_token_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    kv_len: usize,
) -> Cost {
    let mut c = crate::scheduler::timing::per_token_cost(cfg, mapping, params);
    c += mha_token_cost(cfg, params, kv_len);
    c
}

/// Cost of one chunked-prefill replay: `chunk` prompt positions entering
/// the cache at length `base_kv`, sharing each analog pass with lanes =
/// positions (`sim::prefill`).
///
/// Two views, both honest:
/// * `per_position` — identical, entry for entry, to
///   [`decode_token_cost`] at each position's KV length. The *physical*
///   per-position work is unchanged by chunking: every position's
///   activations are driven and every scheduled column converted
///   regardless of how positions are grouped, so energy and per-position
///   accounting must not (and do not) change — `tests/prop_prefill.rs`
///   pins this bit-for-bit against token-by-token ingestion.
/// * `chunk_ns` — the chunk's modeled wall latency when its positions
///   stream back-to-back through the same pass schedule: the row-drive
///   setup of each analog pass is paid once per chunk (positions pipeline
///   behind the sample-and-hold/ADC stream), so the serial per-position
///   drive time of positions 2..C collapses. Conversions, MHA and DPU
///   work still serialize per position. At `chunk == 1` this equals
///   `decode_token_cost(..).latency.critical_ns()` exactly.
#[derive(Clone, Debug)]
pub struct PrefillChunkCost {
    /// Per-position cost records (position order), exactly the
    /// token-by-token costs.
    pub per_position: Vec<Cost>,
    /// Modeled pipelined latency of the whole chunk (ns).
    pub chunk_ns: f64,
}

/// Chunk-aware extension of [`decode_token_cost`]: see
/// [`PrefillChunkCost`] for the model.
pub fn prefill_chunk_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    base_kv: usize,
    chunk: usize,
) -> PrefillChunkCost {
    let per_position: Vec<Cost> = (0..chunk)
        .map(|i| decode_token_cost(cfg, mapping, params, base_kv + i + 1))
        .collect();
    let serial: f64 = per_position
        .iter()
        .map(|c| c.latency.critical_ns())
        .sum();
    let para = crate::scheduler::timing::per_token_cost(cfg, mapping, params);
    let chunk_ns = serial - chunk.saturating_sub(1) as f64 * para.latency.analog_ns;
    PrefillChunkCost {
        per_position,
        chunk_ns,
    }
}

/// Modeled cost of one speculative verify round (`sim::speculate`,
/// DESIGN.md §6d): `lanes` positions — the pending token plus the
/// draft's proposals — entering the cache at length `base_kv` through
/// ONE chunked replay (lanes = positions, exactly a prefill chunk).
///
/// Honest accounting, both ways:
/// * `per_lane` — one [`decode_token_cost`] record per fed position,
///   **rejected lanes included**: a lane that loses the acceptance race
///   still drove its rows and converted its columns, so its analog/ADC
///   energy is real and stays on the bill. Entry-for-entry these match
///   what `chunk_step` records into the slot trace
///   (`tests/prop_speculative.rs` pins the equality bitwise).
/// * `round_ns` — the round's modeled wall latency: the verify replay
///   is a single pipelined pass over the chunk (row-drive setup paid
///   once, conversions/MHA serial per lane — the
///   [`prefill_chunk_cost`] latency model), NOT `lanes` sequential
///   decode steps. This is the whole speculative win: K+1 positions
///   for one pass's latency, paid for in (possibly wasted) lane energy.
#[derive(Clone, Debug)]
pub struct SpeculativeRoundCost {
    /// Per-lane cost records in fed order (rejected lanes included).
    pub per_lane: Vec<Cost>,
    /// Modeled pipelined latency of the whole verify replay (ns).
    pub round_ns: f64,
}

impl SpeculativeRoundCost {
    /// Summed energy of every lane (nJ) — accepted or not.
    pub fn energy_nj(&self) -> f64 {
        self.per_lane.iter().map(|c| c.energy.total_nj()).sum()
    }
}

/// Cost of one speculative verify round: see [`SpeculativeRoundCost`].
/// The verify replay *is* a prefill chunk physically, so this delegates
/// to [`prefill_chunk_cost`] — one latency model, no drift.
pub fn speculative_round_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    base_kv: usize,
    lanes: usize,
) -> SpeculativeRoundCost {
    let pc = prefill_chunk_cost(cfg, mapping, params, base_kv, lanes);
    SpeculativeRoundCost {
        per_lane: pc.per_position,
        round_ns: pc.chunk_ns,
    }
}

/// Sum a slice of per-token costs (shared by [`DecodeTrace::total`] and
/// `DecodeResult::total` so the aggregation can't drift between them).
pub fn sum_costs(costs: &[Cost]) -> Cost {
    let mut t = Cost::default();
    for c in costs {
        t += *c;
    }
    t
}

/// Per-token cost accounting of one autoregressive decode run.
#[derive(Clone, Debug, Default)]
pub struct DecodeTrace {
    /// Cost of token `i` (position order).
    pub per_token: Vec<Cost>,
}

impl DecodeTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, cost: Cost) {
        self.per_token.push(cost);
    }

    pub fn clear(&mut self) {
        self.per_token.clear();
    }

    pub fn tokens(&self) -> usize {
        self.per_token.len()
    }

    /// Summed cost of every decoded token.
    pub fn total(&self) -> Cost {
        sum_costs(&self.per_token)
    }

    /// Mean critical-path latency per token (ns).
    pub fn mean_token_ns(&self) -> f64 {
        if self.per_token.is_empty() {
            return 0.0;
        }
        self.total().latency.critical_ns() / self.per_token.len() as f64
    }

    /// Mean energy per token (nJ).
    pub fn mean_token_nj(&self) -> f64 {
        if self.per_token.is_empty() {
            return 0.0;
        }
        self.total().energy.total_nj() / self.per_token.len() as f64
    }

    /// JSON export: one record per token with the component breakdown.
    pub fn to_json(&self) -> Json {
        arr(self.per_token.iter().enumerate().map(|(i, c)| {
            obj(vec![
                ("token", num(i as f64)),
                ("latency_ns", num(c.latency.critical_ns())),
                ("analog_ns", num(c.latency.analog_ns)),
                ("adc_ns", num(c.latency.adc_ns)),
                ("mha_ns", num(c.latency.mha_ns)),
                ("energy_nj", num(c.energy.total_nj())),
            ])
        }))
    }
}

/// Off-chip activation hand-off events per lane per pipeline hop
/// (`sim::shard`): a lane's `d_model` activation vector leaving chip
/// `k` and entering chip `k+1` is serialized out and deserialized in —
/// two Table-I communication events, charged at the same operating
/// point as the on-chip R→L / L→out gathers (`scheduler::timing`).
pub const SHARD_HOP_COMM_EVENTS: f64 = 2.0;

/// Modeled cost of moving one microbatch of `lanes` activation vectors
/// across one inter-chip hop of the layer-sharded pipeline.
pub fn shard_transfer_cost(params: &CimParams, lanes: usize) -> Cost {
    let events = SHARD_HOP_COMM_EVENTS * lanes as f64;
    Cost {
        latency: Latency {
            comm_ns: events * params.t_comm_ns,
            ..Default::default()
        },
        energy: Energy {
            comm_nj: events * params.e_comm_nj,
            ..Default::default()
        },
    }
}

/// Cost of one token position through ONE pipeline stage: the stage
/// mapping's parameterized-matmul path (`per_token_cost` iterates only
/// the layers present in the stage's ops, so a per-stage mapping prices
/// exactly that stage's Para + DPU work) plus the stage's share of the
/// cache-proportional MHA bill (`stage_layers` of the model's layers
/// live on this chip). Summed over a partition of the layers, the
/// stage costs reproduce the single-chip [`decode_token_cost`] —
/// sharding relocates work, it does not change it.
pub fn stage_token_cost(
    cfg: &ModelConfig,
    stage_mapping: &ModelMapping,
    params: &CimParams,
    kv_len: usize,
    stage_layers: usize,
) -> Cost {
    let mut c = crate::scheduler::timing::per_token_cost(cfg, stage_mapping, params);
    c += mha_layers_cost(params, kv_len, stage_layers);
    c
}

/// Modeled wall latency of one microbatch chunk (`chunk` positions
/// entering at cache length `base_kv`) through ONE pipeline stage —
/// the [`prefill_chunk_cost`] pipelined-latency idiom restricted to
/// the stage's mapping: the stage's analog row-drive is paid once per
/// chunk, conversions/MHA/DPU serialize per position.
pub fn stage_chunk_ns(
    cfg: &ModelConfig,
    stage_mapping: &ModelMapping,
    params: &CimParams,
    base_kv: usize,
    chunk: usize,
    stage_layers: usize,
) -> f64 {
    let serial: f64 = (0..chunk)
        .map(|i| {
            stage_token_cost(cfg, stage_mapping, params, base_kv + i + 1, stage_layers)
                .latency
                .critical_ns()
        })
        .sum();
    let para = crate::scheduler::timing::per_token_cost(cfg, stage_mapping, params);
    serial - chunk.saturating_sub(1) as f64 * para.latency.analog_ns
}

/// One stage's analog window for one microbatch on the per-stage
/// pipeline timeline.
#[derive(Clone, Debug)]
pub struct StageWindow {
    pub stage: usize,
    pub microbatch: usize,
    pub start_ns: f64,
    pub end_ns: f64,
}

/// The per-stage timeline of one pipelined step over a layer-sharded
/// chip chain (`sim::shard`): stage `s` processes microbatch `m` only
/// after stage `s-1` finished it (plus the inter-chip activation
/// transfer) and after stage `s` finished microbatch `m-1` — the
/// classic pipeline recurrence. Stages overlap their analog windows
/// across *different* microbatches; within one microbatch the layer
/// order (and hence the replayed f32 stream) is untouched.
#[derive(Clone, Debug, Default)]
pub struct PipelineTimeline {
    /// Every (stage, microbatch) window, stage-major.
    pub windows: Vec<StageWindow>,
    /// End of the last stage's last window (ns).
    pub makespan_ns: f64,
    /// Busy time per stage (ns) — Σ of its window durations.
    pub stage_busy_ns: Vec<f64>,
    /// Total inter-chip transfer latency charged (ns).
    pub transfer_ns: f64,
    /// What a single chip would take for the same work, no transfers
    /// (ns). [`pipeline_timeline`] seeds it with every stage window
    /// back to back; `sim::shard` replaces that with the measured
    /// full-mapping chunk cost (identical for Linear/SparseMap, whose
    /// per-op geometry is list-independent; DenseMap packs a layer
    /// subset differently than the whole model, so the honest baseline
    /// is the 1-chip mapping, not the stage sum).
    pub serial_ns: f64,
}

impl PipelineTimeline {
    /// Fraction of stage-time slots idle within the makespan:
    /// `1 - Σ busy / (stages · makespan)`. Zero for a single stage;
    /// approaches zero as in-flight microbatch depth grows past the
    /// stage count.
    pub fn bubble_fraction(&self) -> f64 {
        let stages = self.stage_busy_ns.len();
        if stages == 0 || self.makespan_ns <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.stage_busy_ns.iter().sum();
        (1.0 - busy / (stages as f64 * self.makespan_ns)).max(0.0)
    }

    /// Modeled steady-state speedup over one chip doing the same work
    /// serially: `serial_ns / makespan_ns`.
    pub fn speedup_vs_1chip(&self) -> f64 {
        if self.makespan_ns <= 0.0 {
            return 1.0;
        }
        self.serial_ns / self.makespan_ns
    }
}

/// Build the pipeline timeline from per-stage per-microbatch window
/// durations (`stage_ns[s][m]`, every stage listing every microbatch)
/// and the per-microbatch inter-chip transfer latency charged on each
/// of the `stages - 1` hops (`transfer_ns[m]`).
pub fn pipeline_timeline(stage_ns: &[Vec<f64>], transfer_ns: &[f64]) -> PipelineTimeline {
    let stages = stage_ns.len();
    if stages == 0 {
        return PipelineTimeline::default();
    }
    let micro = stage_ns[0].len();
    assert!(
        stage_ns.iter().all(|s| s.len() == micro),
        "every stage must list every microbatch"
    );
    assert_eq!(transfer_ns.len(), micro, "one transfer cost per microbatch");
    let mut windows = Vec::with_capacity(stages * micro);
    let mut stage_busy_ns = vec![0.0f64; stages];
    // end[m] tracks, while sweeping stage s, when stage s-1 finished
    // microbatch m; stage_free is when stage s finished its previous one
    let mut prev_end = vec![0.0f64; micro];
    let mut transfer_total = 0.0f64;
    let mut serial_ns = 0.0f64;
    for (s, durs) in stage_ns.iter().enumerate() {
        let mut stage_free = 0.0f64;
        for (m, &dur) in durs.iter().enumerate() {
            let ready = if s == 0 {
                0.0
            } else {
                transfer_total += transfer_ns[m];
                prev_end[m] + transfer_ns[m]
            };
            let start = ready.max(stage_free);
            let end = start + dur;
            windows.push(StageWindow {
                stage: s,
                microbatch: m,
                start_ns: start,
                end_ns: end,
            });
            stage_busy_ns[s] += dur;
            serial_ns += dur;
            stage_free = end;
            prev_end[m] = end;
        }
    }
    let makespan_ns = prev_end.iter().cloned().fold(0.0f64, f64::max);
    PipelineTimeline {
        windows,
        makespan_ns,
        stage_busy_ns,
        transfer_ns: transfer_total,
        serial_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::map_model;

    #[test]
    fn trace_makespan_matches_cost_model() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let trace = Trace::of_token(&cfg, &mm, &params);
            let cost = crate::scheduler::timing::per_token_cost(&cfg, &mm, &params);
            let want = cost.latency.critical_ns();
            let got = trace.makespan_ns();
            assert!(
                (got - want).abs() < 0.02 * want,
                "{strategy:?}: trace {got} vs model {want}"
            );
        }
    }

    #[test]
    fn events_ordered_and_nonnegative() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::SparseMap);
        let trace = Trace::of_token(&cfg, &mm, &params);
        assert!(!trace.events.is_empty());
        for e in &trace.events {
            assert!(e.t_end_ns >= e.t_start_ns);
            assert!(e.t_start_ns >= 0.0);
        }
    }

    #[test]
    fn json_export_parses_back() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::DenseMap);
        let trace = Trace::of_token(&cfg, &mm, &params);
        let text = trace.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), trace.events.len());
    }

    #[test]
    fn decode_cost_grows_with_kv_length() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::DenseMap);
        let c1 = decode_token_cost(&cfg, &mm, &params, 1);
        let c32 = decode_token_cost(&cfg, &mm, &params, 32);
        assert!(c32.latency.critical_ns() > c1.latency.critical_ns());
        assert!(c32.latency.mha_ns > c1.latency.mha_ns);
        assert!(c32.energy.mha_nj > c1.energy.mha_nj);
        // the para path is position-independent
        assert!((c32.latency.adc_ns - c1.latency.adc_ns).abs() < 1e-9);
    }

    #[test]
    fn prefill_chunk_cost_matches_token_costs_per_position() {
        // per-position records must equal decode_token_cost exactly (the
        // bit-identical accounting chunked prefill is tested against),
        // and the pipelined chunk latency must collapse the repeated
        // row-drive time without ever beating a single position.
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let base = 3usize;
            let chunk = 5usize;
            let pc = prefill_chunk_cost(&cfg, &mm, &params, base, chunk);
            assert_eq!(pc.per_position.len(), chunk);
            for (i, c) in pc.per_position.iter().enumerate() {
                let want = decode_token_cost(&cfg, &mm, &params, base + i + 1);
                assert_eq!(c.latency, want.latency, "{strategy:?} pos {i}");
                assert_eq!(c.energy, want.energy, "{strategy:?} pos {i}");
            }
            let serial: f64 = pc
                .per_position
                .iter()
                .map(|c| c.latency.critical_ns())
                .sum();
            assert!(
                pc.chunk_ns < serial,
                "{strategy:?}: chunking must amortize drive time \
                 ({} !< {serial})",
                pc.chunk_ns
            );
            assert!(
                pc.chunk_ns >= pc.per_position[0].latency.critical_ns(),
                "{strategy:?}: a chunk can't beat one position"
            );
            // chunk of one IS the token cost
            let one = prefill_chunk_cost(&cfg, &mm, &params, base, 1);
            let want = decode_token_cost(&cfg, &mm, &params, base + 1);
            assert_eq!(one.chunk_ns, want.latency.critical_ns());
        }
    }

    #[test]
    fn speculative_round_cost_is_honest() {
        // per-lane records equal decode_token_cost exactly (rejected
        // lanes pay like accepted ones), and the round latency is the
        // single pipelined pass — strictly cheaper than serial decode
        // for any multi-lane round, never cheaper than one position.
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let base = 5usize;
            let lanes = 4usize;
            let rc = speculative_round_cost(&cfg, &mm, &params, base, lanes);
            assert_eq!(rc.per_lane.len(), lanes);
            for (i, c) in rc.per_lane.iter().enumerate() {
                let want = decode_token_cost(&cfg, &mm, &params, base + i + 1);
                assert_eq!(c.latency, want.latency, "{strategy:?} lane {i}");
                assert_eq!(c.energy, want.energy, "{strategy:?} lane {i}");
            }
            let serial: f64 = rc
                .per_lane
                .iter()
                .map(|c| c.latency.critical_ns())
                .sum();
            assert!(rc.round_ns < serial, "{strategy:?}: no pipeline win");
            assert!(rc.round_ns >= rc.per_lane[0].latency.critical_ns());
            assert!(rc.energy_nj() > 0.0);
            // the verify replay is physically a prefill chunk — one model
            let pc = prefill_chunk_cost(&cfg, &mm, &params, base, lanes);
            assert_eq!(rc.round_ns, pc.chunk_ns, "{strategy:?}: model drift");
        }
    }

    #[test]
    fn decode_trace_accumulates_and_exports() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::SparseMap);
        let mut tr = DecodeTrace::new();
        for kv in 1..=4 {
            tr.record(decode_token_cost(&cfg, &mm, &params, kv));
        }
        assert_eq!(tr.tokens(), 4);
        assert!(tr.mean_token_ns() > 0.0);
        assert!(tr.mean_token_nj() > 0.0);
        let parsed = Json::parse(&tr.to_json().to_string()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 4);
        tr.clear();
        assert_eq!(tr.tokens(), 0);
        assert_eq!(tr.mean_token_ns(), 0.0);
    }

    #[test]
    fn densemap_arrays_busier_than_sparse() {
        // capacity packing concentrates work on fewer arrays
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let sp = map_model(&cfg, &params, Strategy::SparseMap);
        let de = map_model(&cfg, &params, Strategy::DenseMap);
        let busiest = |mm: &ModelMapping| {
            let tr = Trace::of_token(&cfg, mm, &params);
            (0..mm.arrays)
                .map(|a| tr.array_busy_ns(a))
                .fold(0.0f64, f64::max)
        };
        assert!(busiest(&de) > busiest(&sp));
    }

    #[test]
    fn pipeline_timeline_classic_recurrence() {
        // S stages x M equal microbatches, no transfer: makespan is the
        // textbook (S + M - 1) * t, bubble = 1 - SM / (S(S+M-1))
        let (s, m, t) = (4usize, 4usize, 100.0f64);
        let stage_ns = vec![vec![t; m]; s];
        let tl = pipeline_timeline(&stage_ns, &vec![0.0; m]);
        assert_eq!(tl.windows.len(), s * m);
        assert!((tl.makespan_ns - (s + m - 1) as f64 * t).abs() < 1e-9);
        assert!((tl.serial_ns - (s * m) as f64 * t).abs() < 1e-9);
        let want_bubble = 1.0 - (s * m) as f64 / (s * (s + m - 1)) as f64;
        assert!((tl.bubble_fraction() - want_bubble).abs() < 1e-9);
        let want_speedup = (s * m) as f64 / (s + m - 1) as f64;
        assert!((tl.speedup_vs_1chip() - want_speedup).abs() < 1e-9);
        // windows never overlap per stage, never run a microbatch
        // before its previous stage finished it
        for w in &tl.windows {
            if w.stage > 0 {
                let prev = tl
                    .windows
                    .iter()
                    .find(|p| p.stage == w.stage - 1 && p.microbatch == w.microbatch)
                    .unwrap();
                assert!(w.start_ns >= prev.end_ns - 1e-9);
            }
        }
    }

    #[test]
    fn pipeline_timeline_single_stage_has_no_bubbles() {
        let tl = pipeline_timeline(&[vec![50.0, 70.0, 30.0]], &[0.0, 0.0, 0.0]);
        assert!((tl.makespan_ns - 150.0).abs() < 1e-9);
        assert_eq!(tl.bubble_fraction(), 0.0);
        assert!((tl.speedup_vs_1chip() - 1.0).abs() < 1e-9);
        assert_eq!(tl.transfer_ns, 0.0);
    }

    #[test]
    fn pipeline_timeline_charges_transfers_on_every_hop() {
        // 2 stages, 2 microbatches, transfer 10 per microbatch per hop
        let stage_ns = vec![vec![100.0, 100.0], vec![100.0, 100.0]];
        let tl = pipeline_timeline(&stage_ns, &[10.0, 10.0]);
        // hop count = (stages-1) * microbatches = 2
        assert!((tl.transfer_ns - 20.0).abs() < 1e-9);
        // m0: s0 [0,100], s1 [110,210]; m1: s0 [100,200], s1 [210,310]
        assert!((tl.makespan_ns - 310.0).abs() < 1e-9);
        // the serial baseline pays no transfers
        assert!((tl.serial_ns - 400.0).abs() < 1e-9);
    }

    #[test]
    fn pipeline_timeline_empty_is_inert() {
        let tl = pipeline_timeline(&[], &[]);
        assert_eq!(tl.makespan_ns, 0.0);
        assert_eq!(tl.bubble_fraction(), 0.0);
        assert!((tl.speedup_vs_1chip() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn stage_costs_partition_the_single_chip_bill() {
        // per-stage Para+DPU+MHA costs over a layer partition sum back
        // to the whole-model decode_token_cost: exactly for Linear and
        // SparseMap (their per-op geometry is independent of the op
        // list), approximately for DenseMap (capacity packing is a
        // whole-list decision, so per-chip packing of a layer subset
        // may legitimately co-locate blocks differently)
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let ops = crate::model::para_ops(&cfg);
        for strategy in Strategy::all() {
            let full = crate::mapping::map_ops(&cfg, &ops, &params, strategy);
            let kv = 7usize;
            let want = decode_token_cost(&cfg, &full, &params, kv);
            let mut got = Cost::default();
            for l in 0..cfg.dec_layers {
                let stage_ops: Vec<_> = ops
                    .iter()
                    .filter(|o| o.layer == l)
                    .cloned()
                    .collect();
                let sm = crate::mapping::map_ops(&cfg, &stage_ops, &params, strategy);
                got += stage_token_cost(&cfg, &sm, &params, kv, 1);
            }
            let rel = |a: f64, b: f64| (a - b).abs() / b.max(1e-12);
            let tol = match strategy {
                Strategy::DenseMap => 1.0, // within 2x of the 1-chip bill
                _ => 1e-9,
            };
            assert!(
                rel(got.latency.critical_ns(), want.latency.critical_ns()) <= tol,
                "{strategy:?}: stage latency sum drifted from the single-chip bill"
            );
            assert!(
                rel(got.energy.total_nj(), want.energy.total_nj()) <= tol,
                "{strategy:?}: stage energy sum drifted"
            );
        }
    }
}
