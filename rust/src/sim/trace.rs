//! Execution trace: a timestamped record of scheduler events for one
//! token pass, exportable as JSON (for external timeline visualisation)
//! and queryable for per-resource occupancy — the observability layer of
//! the simulator. [`DecodeTrace`] extends it to autoregressive decode:
//! per-token latency/energy with the growing-KV-cache attention cost.

use crate::cim::{CimParams, Cost, Energy, Latency};
use crate::mapping::{ModelMapping, Strategy};
use crate::model::ModelConfig;
use crate::scheduler::{adc_bits_for, usable_adcs};
use crate::util::json::{arr, num, obj, s, Json};

/// One traced scheduler event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub t_start_ns: f64,
    pub t_end_ns: f64,
    /// `analog` | `convert` | `comm` | `dpu`
    pub kind: &'static str,
    pub op: String,
    pub layer: usize,
    /// Arrays occupied by the event.
    pub arrays: Vec<usize>,
}

/// A full per-token trace.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Build the slot-model trace of one token pass over a mapping.
    pub fn of_token(
        cfg: &ModelConfig,
        mapping: &ModelMapping,
        params: &CimParams,
    ) -> Trace {
        let mut t = 0.0f64;
        let mut events = Vec::new();
        let bits = adc_bits_for(params, mapping.strategy, mapping.b);
        let adcs = usable_adcs(params, mapping.strategy, mapping.b);
        let t_conv = crate::cim::adc::t_conversion_ns(params, bits);
        let layers: std::collections::BTreeSet<usize> =
            mapping.ops.iter().map(|o| o.layer).collect();
        for layer in layers {
            // group ops of this layer by slot order (same as the timing
            // model: qkv | wo | ffn1 | ffn2)
            let slot_of = |name: &str| -> usize {
                if name.ends_with(".wq") || name.ends_with(".wk") || name.ends_with(".wv") {
                    0
                } else if name.ends_with(".wo") {
                    1
                } else if name.ends_with(".ffn1") {
                    2
                } else {
                    3
                }
            };
            let mut slots: Vec<Vec<usize>> = vec![Vec::new(); 4];
            for (i, op) in mapping.ops.iter().enumerate() {
                if op.layer == layer {
                    slots[slot_of(&op.name)].push(i);
                }
            }
            for slot in slots.iter().filter(|sl| !sl.is_empty()) {
                let mut slot_end = t;
                for &oi in slot {
                    let op = &mapping.ops[oi];
                    let drive = params.t_drive_ns()
                        * if mapping.strategy == Strategy::DenseMap {
                            2.0 * op.analog_phases as f64
                        } else {
                            1.0
                        };
                    let conv = (op.convs_per_array as f64 / adcs as f64).ceil()
                        * t_conv
                        * if mapping.strategy == Strategy::DenseMap {
                            (1.0 + crate::scheduler::timing::DENSE_STAGE_SERIALIZATION)
                                * op.analog_phases as f64
                        } else {
                            1.0
                        };
                    events.push(TraceEvent {
                        t_start_ns: t,
                        t_end_ns: t + drive,
                        kind: "analog",
                        op: op.name.clone(),
                        layer,
                        arrays: op.arrays.clone(),
                    });
                    events.push(TraceEvent {
                        t_start_ns: t + drive,
                        t_end_ns: t + drive + conv,
                        kind: "convert",
                        op: op.name.clone(),
                        layer,
                        arrays: op.arrays.clone(),
                    });
                    slot_end = slot_end.max(t + drive + conv);
                }
                t = slot_end;
            }
        }
        let _ = cfg;
        Trace { events }
    }

    /// Makespan of the trace (ns).
    pub fn makespan_ns(&self) -> f64 {
        self.events.iter().fold(0.0, |m, e| m.max(e.t_end_ns))
    }

    /// Busy time of one array (ns).
    pub fn array_busy_ns(&self, array: usize) -> f64 {
        self.events
            .iter()
            .filter(|e| e.arrays.contains(&array))
            .map(|e| e.t_end_ns - e.t_start_ns)
            .sum()
    }

    /// JSON export (chrome-tracing-like flat list).
    pub fn to_json(&self) -> Json {
        arr(self.events.iter().map(|e| {
            obj(vec![
                ("ts", num(e.t_start_ns)),
                ("dur", num(e.t_end_ns - e.t_start_ns)),
                ("kind", s(e.kind)),
                ("op", s(&e.op)),
                ("layer", num(e.layer as f64)),
                ("arrays", num(e.arrays.len() as f64)),
            ])
        }))
    }
}

/// NonPara attention cost of one decode step at a given KV-cache length:
/// per layer, the digital MHA unit performs one `q · K^T` sweep and one
/// `A · V` accumulation over the cache — two vector events per cached
/// position at Table-I `Add` granularity. This is the component that
/// *grows* with the token position (the memory-bound decode regime the
/// paper motivates); the parameterized-matmul cost stays constant.
pub fn mha_token_cost(cfg: &ModelConfig, params: &CimParams, kv_len: usize) -> Cost {
    let layers = cfg.total_layers().max(1) as f64;
    let events = 2.0 * kv_len as f64 * layers;
    Cost {
        latency: Latency {
            mha_ns: events * params.t_add_ns,
            ..Default::default()
        },
        energy: Energy {
            mha_nj: events * params.e_add_nj,
            ..Default::default()
        },
    }
}

/// Full cost of decoding one token at KV length `kv_len`: the mapped
/// parameterized-matmul path (`scheduler::timing::per_token_cost`) plus
/// the cache-proportional MHA work.
pub fn decode_token_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    kv_len: usize,
) -> Cost {
    let mut c = crate::scheduler::timing::per_token_cost(cfg, mapping, params);
    c += mha_token_cost(cfg, params, kv_len);
    c
}

/// Cost of one chunked-prefill replay: `chunk` prompt positions entering
/// the cache at length `base_kv`, sharing each analog pass with lanes =
/// positions (`sim::prefill`).
///
/// Two views, both honest:
/// * `per_position` — identical, entry for entry, to
///   [`decode_token_cost`] at each position's KV length. The *physical*
///   per-position work is unchanged by chunking: every position's
///   activations are driven and every scheduled column converted
///   regardless of how positions are grouped, so energy and per-position
///   accounting must not (and do not) change — `tests/prop_prefill.rs`
///   pins this bit-for-bit against token-by-token ingestion.
/// * `chunk_ns` — the chunk's modeled wall latency when its positions
///   stream back-to-back through the same pass schedule: the row-drive
///   setup of each analog pass is paid once per chunk (positions pipeline
///   behind the sample-and-hold/ADC stream), so the serial per-position
///   drive time of positions 2..C collapses. Conversions, MHA and DPU
///   work still serialize per position. At `chunk == 1` this equals
///   `decode_token_cost(..).latency.critical_ns()` exactly.
#[derive(Clone, Debug)]
pub struct PrefillChunkCost {
    /// Per-position cost records (position order), exactly the
    /// token-by-token costs.
    pub per_position: Vec<Cost>,
    /// Modeled pipelined latency of the whole chunk (ns).
    pub chunk_ns: f64,
}

/// Chunk-aware extension of [`decode_token_cost`]: see
/// [`PrefillChunkCost`] for the model.
pub fn prefill_chunk_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    base_kv: usize,
    chunk: usize,
) -> PrefillChunkCost {
    let per_position: Vec<Cost> = (0..chunk)
        .map(|i| decode_token_cost(cfg, mapping, params, base_kv + i + 1))
        .collect();
    let serial: f64 = per_position
        .iter()
        .map(|c| c.latency.critical_ns())
        .sum();
    let para = crate::scheduler::timing::per_token_cost(cfg, mapping, params);
    let chunk_ns = serial - chunk.saturating_sub(1) as f64 * para.latency.analog_ns;
    PrefillChunkCost {
        per_position,
        chunk_ns,
    }
}

/// Modeled cost of one speculative verify round (`sim::speculate`,
/// DESIGN.md §6d): `lanes` positions — the pending token plus the
/// draft's proposals — entering the cache at length `base_kv` through
/// ONE chunked replay (lanes = positions, exactly a prefill chunk).
///
/// Honest accounting, both ways:
/// * `per_lane` — one [`decode_token_cost`] record per fed position,
///   **rejected lanes included**: a lane that loses the acceptance race
///   still drove its rows and converted its columns, so its analog/ADC
///   energy is real and stays on the bill. Entry-for-entry these match
///   what `chunk_step` records into the slot trace
///   (`tests/prop_speculative.rs` pins the equality bitwise).
/// * `round_ns` — the round's modeled wall latency: the verify replay
///   is a single pipelined pass over the chunk (row-drive setup paid
///   once, conversions/MHA serial per lane — the
///   [`prefill_chunk_cost`] latency model), NOT `lanes` sequential
///   decode steps. This is the whole speculative win: K+1 positions
///   for one pass's latency, paid for in (possibly wasted) lane energy.
#[derive(Clone, Debug)]
pub struct SpeculativeRoundCost {
    /// Per-lane cost records in fed order (rejected lanes included).
    pub per_lane: Vec<Cost>,
    /// Modeled pipelined latency of the whole verify replay (ns).
    pub round_ns: f64,
}

impl SpeculativeRoundCost {
    /// Summed energy of every lane (nJ) — accepted or not.
    pub fn energy_nj(&self) -> f64 {
        self.per_lane.iter().map(|c| c.energy.total_nj()).sum()
    }
}

/// Cost of one speculative verify round: see [`SpeculativeRoundCost`].
/// The verify replay *is* a prefill chunk physically, so this delegates
/// to [`prefill_chunk_cost`] — one latency model, no drift.
pub fn speculative_round_cost(
    cfg: &ModelConfig,
    mapping: &ModelMapping,
    params: &CimParams,
    base_kv: usize,
    lanes: usize,
) -> SpeculativeRoundCost {
    let pc = prefill_chunk_cost(cfg, mapping, params, base_kv, lanes);
    SpeculativeRoundCost {
        per_lane: pc.per_position,
        round_ns: pc.chunk_ns,
    }
}

/// Sum a slice of per-token costs (shared by [`DecodeTrace::total`] and
/// `DecodeResult::total` so the aggregation can't drift between them).
pub fn sum_costs(costs: &[Cost]) -> Cost {
    let mut t = Cost::default();
    for c in costs {
        t += *c;
    }
    t
}

/// Per-token cost accounting of one autoregressive decode run.
#[derive(Clone, Debug, Default)]
pub struct DecodeTrace {
    /// Cost of token `i` (position order).
    pub per_token: Vec<Cost>,
}

impl DecodeTrace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, cost: Cost) {
        self.per_token.push(cost);
    }

    pub fn clear(&mut self) {
        self.per_token.clear();
    }

    pub fn tokens(&self) -> usize {
        self.per_token.len()
    }

    /// Summed cost of every decoded token.
    pub fn total(&self) -> Cost {
        sum_costs(&self.per_token)
    }

    /// Mean critical-path latency per token (ns).
    pub fn mean_token_ns(&self) -> f64 {
        if self.per_token.is_empty() {
            return 0.0;
        }
        self.total().latency.critical_ns() / self.per_token.len() as f64
    }

    /// Mean energy per token (nJ).
    pub fn mean_token_nj(&self) -> f64 {
        if self.per_token.is_empty() {
            return 0.0;
        }
        self.total().energy.total_nj() / self.per_token.len() as f64
    }

    /// JSON export: one record per token with the component breakdown.
    pub fn to_json(&self) -> Json {
        arr(self.per_token.iter().enumerate().map(|(i, c)| {
            obj(vec![
                ("token", num(i as f64)),
                ("latency_ns", num(c.latency.critical_ns())),
                ("analog_ns", num(c.latency.analog_ns)),
                ("adc_ns", num(c.latency.adc_ns)),
                ("mha_ns", num(c.latency.mha_ns)),
                ("energy_nj", num(c.energy.total_nj())),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::map_model;

    #[test]
    fn trace_makespan_matches_cost_model() {
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let trace = Trace::of_token(&cfg, &mm, &params);
            let cost = crate::scheduler::timing::per_token_cost(&cfg, &mm, &params);
            let want = cost.latency.critical_ns();
            let got = trace.makespan_ns();
            assert!(
                (got - want).abs() < 0.02 * want,
                "{strategy:?}: trace {got} vs model {want}"
            );
        }
    }

    #[test]
    fn events_ordered_and_nonnegative() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::SparseMap);
        let trace = Trace::of_token(&cfg, &mm, &params);
        assert!(!trace.events.is_empty());
        for e in &trace.events {
            assert!(e.t_end_ns >= e.t_start_ns);
            assert!(e.t_start_ns >= 0.0);
        }
    }

    #[test]
    fn json_export_parses_back() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::DenseMap);
        let trace = Trace::of_token(&cfg, &mm, &params);
        let text = trace.to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), trace.events.len());
    }

    #[test]
    fn decode_cost_grows_with_kv_length() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::DenseMap);
        let c1 = decode_token_cost(&cfg, &mm, &params, 1);
        let c32 = decode_token_cost(&cfg, &mm, &params, 32);
        assert!(c32.latency.critical_ns() > c1.latency.critical_ns());
        assert!(c32.latency.mha_ns > c1.latency.mha_ns);
        assert!(c32.energy.mha_nj > c1.energy.mha_nj);
        // the para path is position-independent
        assert!((c32.latency.adc_ns - c1.latency.adc_ns).abs() < 1e-9);
    }

    #[test]
    fn prefill_chunk_cost_matches_token_costs_per_position() {
        // per-position records must equal decode_token_cost exactly (the
        // bit-identical accounting chunked prefill is tested against),
        // and the pipelined chunk latency must collapse the repeated
        // row-drive time without ever beating a single position.
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let base = 3usize;
            let chunk = 5usize;
            let pc = prefill_chunk_cost(&cfg, &mm, &params, base, chunk);
            assert_eq!(pc.per_position.len(), chunk);
            for (i, c) in pc.per_position.iter().enumerate() {
                let want = decode_token_cost(&cfg, &mm, &params, base + i + 1);
                assert_eq!(c.latency, want.latency, "{strategy:?} pos {i}");
                assert_eq!(c.energy, want.energy, "{strategy:?} pos {i}");
            }
            let serial: f64 = pc
                .per_position
                .iter()
                .map(|c| c.latency.critical_ns())
                .sum();
            assert!(
                pc.chunk_ns < serial,
                "{strategy:?}: chunking must amortize drive time \
                 ({} !< {serial})",
                pc.chunk_ns
            );
            assert!(
                pc.chunk_ns >= pc.per_position[0].latency.critical_ns(),
                "{strategy:?}: a chunk can't beat one position"
            );
            // chunk of one IS the token cost
            let one = prefill_chunk_cost(&cfg, &mm, &params, base, 1);
            let want = decode_token_cost(&cfg, &mm, &params, base + 1);
            assert_eq!(one.chunk_ns, want.latency.critical_ns());
        }
    }

    #[test]
    fn speculative_round_cost_is_honest() {
        // per-lane records equal decode_token_cost exactly (rejected
        // lanes pay like accepted ones), and the round latency is the
        // single pipelined pass — strictly cheaper than serial decode
        // for any multi-lane round, never cheaper than one position.
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        for strategy in Strategy::all() {
            let mm = map_model(&cfg, &params, strategy);
            let base = 5usize;
            let lanes = 4usize;
            let rc = speculative_round_cost(&cfg, &mm, &params, base, lanes);
            assert_eq!(rc.per_lane.len(), lanes);
            for (i, c) in rc.per_lane.iter().enumerate() {
                let want = decode_token_cost(&cfg, &mm, &params, base + i + 1);
                assert_eq!(c.latency, want.latency, "{strategy:?} lane {i}");
                assert_eq!(c.energy, want.energy, "{strategy:?} lane {i}");
            }
            let serial: f64 = rc
                .per_lane
                .iter()
                .map(|c| c.latency.critical_ns())
                .sum();
            assert!(rc.round_ns < serial, "{strategy:?}: no pipeline win");
            assert!(rc.round_ns >= rc.per_lane[0].latency.critical_ns());
            assert!(rc.energy_nj() > 0.0);
            // the verify replay is physically a prefill chunk — one model
            let pc = prefill_chunk_cost(&cfg, &mm, &params, base, lanes);
            assert_eq!(rc.round_ns, pc.chunk_ns, "{strategy:?}: model drift");
        }
    }

    #[test]
    fn decode_trace_accumulates_and_exports() {
        let cfg = ModelConfig::tiny();
        let params = CimParams::default();
        let mm = map_model(&cfg, &params, Strategy::SparseMap);
        let mut tr = DecodeTrace::new();
        for kv in 1..=4 {
            tr.record(decode_token_cost(&cfg, &mm, &params, kv));
        }
        assert_eq!(tr.tokens(), 4);
        assert!(tr.mean_token_ns() > 0.0);
        assert!(tr.mean_token_nj() > 0.0);
        let parsed = Json::parse(&tr.to_json().to_string()).unwrap();
        assert_eq!(parsed.as_arr().unwrap().len(), 4);
        tr.clear();
        assert_eq!(tr.tokens(), 0);
        assert_eq!(tr.mean_token_ns(), 0.0);
    }

    #[test]
    fn densemap_arrays_busier_than_sparse() {
        // capacity packing concentrates work on fewer arrays
        let cfg = ModelConfig::bert_large();
        let params = CimParams::default();
        let sp = map_model(&cfg, &params, Strategy::SparseMap);
        let de = map_model(&cfg, &params, Strategy::DenseMap);
        let busiest = |mm: &ModelMapping| {
            let tr = Trace::of_token(&cfg, mm, &params);
            (0..mm.arrays)
                .map(|a| tr.array_busy_ns(a))
                .fold(0.0f64, f64::max)
        };
        assert!(busiest(&de) > busiest(&sp));
    }
}
