//! PJRT runtime: loads the AOT-compiled HLO text artifacts produced by
//! `python/compile/aot.py` and executes them natively — Python is never
//! on this path.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serializes protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md). Executables are compiled once and cached
//! per artifact name.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::monarch::{BlockDiag, MonarchMatrix};
use crate::tensor::Matrix;
use crate::util::json::Json;
use crate::xla;

/// Tensor spec of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow!("spec missing dtype"))?
            .to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?
        {
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or_else(|| anyhow!("artifact missing name"))?
                    .to_string(),
                file: a
                    .get("file")
                    .and_then(|f| f.as_str())
                    .ok_or_else(|| anyhow!("artifact missing file"))?
                    .to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(|i| i.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(|o| o.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()?,
                meta: a.get("meta").cloned().unwrap_or(Json::Null),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Default artifacts directory (repo-relative, overridable via env).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("MONARCH_CIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// PJRT-backed executor with a compile-once executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Cached weight literals for artifacts with a `.weights.bin`
    /// sidecar (see `python/compile/aot.py`): jax >= 0.5 hoists model
    /// constants into leading HLO parameters.
    weights: HashMap<String, Vec<xla::Literal>>,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: HashMap::new(),
            weights: HashMap::new(),
        })
    }

    pub fn with_default_dir() -> Result<Runtime> {
        Self::new(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for an artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling '{name}': {e}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Load (and cache) the weight literals of an artifact with a
    /// `weights_file` sidecar. The sidecar is flat little-endian f32 in
    /// manifest input order; weight inputs are the first `n_weights`.
    fn load_weights(&mut self, name: &str) -> Result<usize> {
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let Some(file) = spec.meta.get("weights_file").and_then(Json::as_str) else {
            return Ok(0);
        };
        let n_weights = spec
            .meta
            .get("n_weights")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("'{name}' has weights_file but no n_weights"))?;
        if self.weights.contains_key(name) {
            return Ok(n_weights);
        }
        let path = self.manifest.dir.join(file);
        let bytes = std::fs::read(&path).with_context(|| format!("reading {path:?}"))?;
        let floats: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let expect: usize = spec.inputs[..n_weights].iter().map(|t| t.elements()).sum();
        if floats.len() != expect {
            bail!(
                "weights sidecar {path:?}: {} floats, manifest expects {expect}",
                floats.len()
            );
        }
        let mut lits = Vec::with_capacity(n_weights);
        let mut off = 0usize;
        for ts in &spec.inputs[..n_weights] {
            let n = ts.elements();
            lits.push(literal_f32(&floats[off..off + n], &ts.shape)?);
            off += n;
        }
        self.weights.insert(name.to_string(), lits);
        Ok(n_weights)
    }

    /// Validate shapes and execute an artifact; returns flattened output
    /// literals (AOT lowers with `return_tuple=True`). For artifacts
    /// with a weights sidecar, `inputs` are only the *dynamic* trailing
    /// inputs — the cached weight literals are prepended automatically.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let n_weights = self.load_weights(name)?;
        let spec = self.manifest.find(name).unwrap().clone();
        let dynamic = &spec.inputs[n_weights..];
        if inputs.len() != dynamic.len() {
            bail!(
                "artifact '{name}' expects {} dynamic inputs, got {}",
                dynamic.len(),
                inputs.len()
            );
        }
        for (i, (lit, ts)) in inputs.iter().zip(dynamic).enumerate() {
            let count = lit.element_count();
            if count != ts.elements() {
                bail!(
                    "input {i} of '{name}': expected {:?} ({} elems), got {count} elems",
                    ts.shape,
                    ts.elements()
                );
            }
        }
        let result = {
            let exe = self.cache.get(name).unwrap();
            if n_weights > 0 {
                let weights = self.weights.get(name).unwrap();
                let all: Vec<&xla::Literal> =
                    weights.iter().chain(inputs.iter()).collect();
                exe.execute::<&xla::Literal>(&all)
            } else {
                exe.execute::<xla::Literal>(inputs)
            }
        }
        .map_err(|e| anyhow!("executing '{name}': {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e}"))?;
        let outs = result
            .to_tuple()
            .map_err(|e| anyhow!("untupling result of '{name}': {e}"))?;
        Ok(outs)
    }

    /// Execute and read back a single f32 output.
    pub fn execute_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.execute(name, inputs)?;
        let first = outs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("'{name}' returned no outputs"))?;
        first
            .to_vec::<f32>()
            .map_err(|e| anyhow!("reading f32 output of '{name}': {e}"))
    }
}

// ---------------------------------------------------------------------------
// Literal conversion helpers
// ---------------------------------------------------------------------------

/// f32 data + shape -> Literal.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} != data len {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e}"))
}

/// i32 data + shape -> Literal (token ids).
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("literal shape {shape:?} != data len {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape literal: {e}"))
}

/// Row-major Matrix -> 2-D Literal.
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    literal_f32(&m.data, &[m.rows, m.cols])
}

/// BlockDiag factor -> (nb, b, b) Literal, the layout the L1 kernels use.
pub fn literal_from_blockdiag(bd: &BlockDiag) -> Result<xla::Literal> {
    literal_f32(&bd.data, &[bd.nblocks, bd.b, bd.b])
}

/// Monarch factors -> (L, R) literals.
pub fn literals_from_monarch(m: &MonarchMatrix) -> Result<(xla::Literal, xla::Literal)> {
    Ok((
        literal_from_blockdiag(&m.l)?,
        literal_from_blockdiag(&m.r)?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shape_validation() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
    }

    #[test]
    fn manifest_parsing_minimal() {
        let dir = std::env::temp_dir().join("monarch_cim_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "x", "file": "x.hlo.txt",
                 "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                 "outputs": [{"shape": [2, 3], "dtype": "float32"}],
                 "meta": {"kind": "test"}}
            ]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[0].elements(), 6);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn manifest_missing_dir_errors() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
