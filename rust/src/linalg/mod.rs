//! Numerical linear algebra substrate: truncated rank-1 SVD via power
//! iteration (the only decomposition the D2S projection needs), plus
//! helpers for validation.
//!
//! Power iteration on `A^T A` converges to the dominant right singular
//! vector; we run the alternating form (v -> A^T A v, u = A v / sigma)
//! with tolerance + iteration caps. For the paper's slice sizes
//! (b x b, b <= 64) this is far faster than a full SVD and exact up to
//! the gap — property tests compare against a 2x2 closed form and
//! against reconstruction-optimality invariants.

use crate::tensor::Matrix;

/// Result of a rank-1 decomposition `A ~= sigma * u v^T`.
#[derive(Clone, Debug)]
pub struct Rank1 {
    pub sigma: f32,
    pub u: Vec<f32>,
    pub v: Vec<f32>,
}

impl Rank1 {
    /// Materialize `sigma * u v^T`.
    pub fn to_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(self.u.len(), self.v.len());
        for (r, &uv) in self.u.iter().enumerate() {
            let s = self.sigma * uv;
            for (c, &vv) in self.v.iter().enumerate() {
                m[(r, c)] = s * vv;
            }
        }
        m
    }
}

fn norm(v: &[f32]) -> f32 {
    v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt() as f32
}

fn normalize(v: &mut [f32]) -> f32 {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

/// Best rank-1 approximation of `a` by alternating power iteration.
///
/// Deterministic: starts from the largest-norm column of `a` (falls back
/// to e_0), which also makes the zero matrix well-defined (sigma = 0).
pub fn rank1_svd(a: &Matrix) -> Rank1 {
    let (m, n) = (a.rows, a.cols);
    // start v := unit vector toward the heaviest column
    let mut v = vec![0.0f32; n];
    let mut best = (0usize, -1.0f64);
    for c in 0..n {
        let cn: f64 = (0..m).map(|r| (a[(r, c)] as f64).powi(2)).sum();
        if cn > best.1 {
            best = (c, cn);
        }
    }
    if best.1 <= 0.0 {
        // zero matrix
        let mut u = vec![0.0; m];
        if m > 0 {
            u[0] = 1.0;
        }
        let mut v = vec![0.0; n];
        if n > 0 {
            v[0] = 1.0;
        }
        return Rank1 { sigma: 0.0, u, v };
    }
    v[best.0] = 1.0;

    let mut u = vec![0.0f32; m];
    let mut sigma = 0.0f32;
    let mut prev_sigma = -1.0f32;
    for _ in 0..200 {
        // u = A v
        for r in 0..m {
            let row = a.row(r);
            u[r] = row.iter().zip(&v).map(|(x, y)| x * y).sum();
        }
        normalize(&mut u);
        // v = A^T u
        for x in v.iter_mut() {
            *x = 0.0;
        }
        for r in 0..m {
            let row = a.row(r);
            let ur = u[r];
            if ur == 0.0 {
                continue;
            }
            for (vx, ax) in v.iter_mut().zip(row) {
                *vx += ur * ax;
            }
        }
        sigma = normalize(&mut v);
        if (sigma - prev_sigma).abs() <= 1e-7 * sigma.max(1.0) {
            break;
        }
        prev_sigma = sigma;
    }
    Rank1 { sigma, u, v }
}

/// Squared Frobenius norm of the rank-1 residual `A - sigma u v^T`.
pub fn rank1_residual_sq(a: &Matrix, r1: &Rank1) -> f64 {
    let mut acc = 0.0f64;
    for r in 0..a.rows {
        for c in 0..a.cols {
            let d = (a[(r, c)] - r1.sigma * r1.u[r] * r1.v[c]) as f64;
            acc += d * d;
        }
    }
    acc
}

/// All singular values of a small matrix via Jacobi one-sided rotation
/// (used only in tests/diagnostics; O(n^3) per sweep).
pub fn singular_values(a: &Matrix) -> Vec<f64> {
    // One-sided Jacobi on columns of a copy.
    let mut w = a.clone();
    let n = w.cols;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for r in 0..w.rows {
                    let (x, y) = (w[(r, p)] as f64, w[(r, q)] as f64);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off += apq * apq;
                if apq.abs() < 1e-15 {
                    continue;
                }
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for r in 0..w.rows {
                    let (x, y) = (w[(r, p)] as f64, w[(r, q)] as f64);
                    w[(r, p)] = (c * x - s * y) as f32;
                    w[(r, q)] = (s * x + c * y) as f32;
                }
            }
        }
        if off < 1e-20 {
            break;
        }
    }
    let mut svs: Vec<f64> = (0..n)
        .map(|c| {
            (0..w.rows)
                .map(|r| (w[(r, c)] as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        })
        .collect();
    svs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    svs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Pcg32;

    #[test]
    fn exact_on_rank1_input() {
        let mut rng = Pcg32::new(1);
        let u: Vec<f32> = rng.normal_vec(6);
        let v: Vec<f32> = rng.normal_vec(4);
        let a = Matrix::from_fn(6, 4, |r, c| 2.5 * u[r] * v[c]);
        let r1 = rank1_svd(&a);
        assert!(rank1_residual_sq(&a, &r1).sqrt() < 1e-4);
    }

    #[test]
    fn zero_matrix_gives_zero_sigma() {
        let a = Matrix::zeros(3, 3);
        let r1 = rank1_svd(&a);
        assert_eq!(r1.sigma, 0.0);
    }

    #[test]
    fn sigma_matches_2x2_closed_form() {
        // A = [[3, 0], [4, 5]]: A^T A has trace 50, det 225 ->
        // eigenvalues (50 ± 40)/2 = {45, 5}, so sigma_1 = sqrt(45).
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 5.0]);
        let r1 = rank1_svd(&a);
        let want = 45.0f64.sqrt();
        assert!(
            ((r1.sigma as f64) - want).abs() < 1e-3,
            "sigma {} want {want}",
            r1.sigma
        );
    }

    #[test]
    fn residual_never_exceeds_norm() {
        forall("rank1 residual <= ||A||", 30, |g| {
            let (m, n) = (g.usize(1, 12), g.usize(1, 12));
            let data = g.normal_vec(m * n);
            let a = Matrix::from_vec(m, n, data);
            let r1 = rank1_svd(&a);
            let res = rank1_residual_sq(&a, &r1).sqrt();
            assert!(res <= a.frobenius() + 1e-4, "res {res} > {}", a.frobenius());
        });
    }

    #[test]
    fn residual_matches_tail_singular_values() {
        let mut rng = Pcg32::new(5);
        let a = Matrix::randn(8, 8, &mut rng);
        let svs = singular_values(&a);
        let tail: f64 = svs[1..].iter().map(|s| s * s).sum();
        let r1 = rank1_svd(&a);
        let res = rank1_residual_sq(&a, &r1);
        assert!(
            (res - tail).abs() < 1e-3 * tail.max(1.0),
            "res {res}, tail {tail}"
        );
    }

    #[test]
    fn unit_vectors_returned() {
        let mut rng = Pcg32::new(6);
        let a = Matrix::randn(5, 7, &mut rng);
        let r1 = rank1_svd(&a);
        assert!((norm(&r1.u) - 1.0).abs() < 1e-4);
        assert!((norm(&r1.v) - 1.0).abs() < 1e-4);
        assert!(r1.sigma > 0.0);
    }
}
