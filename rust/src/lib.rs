//! # monarch-cim
//!
//! Production-grade reproduction of *“Efficient In-Memory Acceleration of
//! Sparse Block Diagonal LLMs”* (de Lima et al., CS.AR 2025): an automated
//! framework that D2S-transforms dense transformer weights into Monarch
//! block-diagonal form, maps the factors onto analog compute-in-memory
//! (CIM) crossbar arrays with latency-optimized (**SparseMap**) and
//! capacity-optimized (**DenseMap**) strategies, and schedules execution
//! with mapping-aware row activation and ADC sharing.
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: D2S pipeline, mapping engine,
//!   scheduler, analog-CIM simulator, DSE/benchmark harness, batching
//!   inference server, CLI.
//! * **L2 (python/compile/model.py)** — Monarch transformer forward in
//!   JAX, AOT-lowered to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — Pallas block-diagonal kernels
//!   called by L2 (interpret mode for CPU PJRT).
//!
//! Python never runs on the request path: `runtime` loads the HLO
//! artifacts through the PJRT C API and executes them natively.

pub mod cim;
pub mod coordinator;
pub mod gpu;
pub mod linalg;
pub mod mapping;
pub mod model;
pub mod monarch;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod xla;
