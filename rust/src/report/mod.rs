//! Figure/table reproduction: renders each of the paper's evaluation
//! artifacts (Fig. 2b, Table I, Fig. 6a/b, Fig. 7a/b, Fig. 8a/b, the
//! §IV-C ADC-resolution claim) as ASCII tables and CSV files under
//! `reports/`.

use std::path::Path;

use crate::cim::{adc, CimParams};
use crate::gpu::{gpu_cost, GpuParams};
use crate::mapping::stats::{fig6_stats, mean_array_reduction, mean_utilization};
use crate::mapping::Strategy;
use crate::model::{count_report, ModelConfig};
use crate::scheduler::timing::cost_report;
use crate::util::stats::geomean;
use crate::util::table::{eng_energy_nj, eng_time_ns, ratio, si, Table};

/// Write a table's CSV under `reports/<name>.csv` (best-effort).
pub fn save_csv(name: &str, t: &Table) {
    let dir = Path::new("reports");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{name}.csv")), t.to_csv());
    }
}

/// Fig. 2b: parameter and FLOP reduction with the Para/NonPara split.
pub fn fig2b() -> Table {
    let mut t = Table::new([
        "model",
        "seq",
        "dense params",
        "monarch params",
        "param red. (para)",
        "param red. (model)",
        "dense FLOPs",
        "monarch FLOPs",
        "FLOPs red.",
        "para FLOPs share",
    ]);
    for cfg in ModelConfig::paper_models() {
        let r = count_report(&cfg);
        t.row([
            r.model.clone(),
            r.seq.to_string(),
            si((r.dense_para_params + r.other_params) as f64),
            si((r.monarch_para_params + r.other_params) as f64),
            ratio(r.para_param_reduction()),
            ratio(r.model_param_reduction()),
            si((r.dense_para_flops + r.nonpara_flops) as f64),
            si((r.monarch_para_flops + r.nonpara_flops) as f64),
            ratio(r.flops_reduction()),
            format!("{:.1}%", 100.0 * r.para_flops_fraction()),
        ]);
    }
    save_csv("fig2b", &t);
    t
}

/// Table I: the active CIM configuration.
pub fn tab1(params: &CimParams) -> Table {
    let mut t = Table::new(["specification", "latency (ns)", "energy (nJ)"]);
    t.row([
        format!("MVM ({0}x{0} PCM)", params.array_dim),
        format!("{}", params.t_mvm_ns),
        format!("{}", params.e_mvm_nj),
    ]);
    t.row([
        format!("ADC SAR ({}b)", params.adc_ref_bits),
        format!("{}", params.t_adc_ref_ns),
        format!("{}", params.e_adc_ref_nj),
    ]);
    t.row([
        "Communication".to_string(),
        format!("{}", params.t_comm_ns),
        format!("{}", params.e_comm_nj),
    ]);
    t.row([
        "LayerNorm".to_string(),
        format!("{}", params.t_layernorm_ns),
        format!("{}", params.e_layernorm_nj),
    ]);
    t.row([
        "ReLU / GeLU / Add".to_string(),
        format!(
            "{} / {} / {}",
            params.t_relu_ns, params.t_gelu_ns, params.t_add_ns
        ),
        format!(
            "{} / {} / {}",
            params.e_relu_nj, params.e_gelu_nj, params.e_add_nj
        ),
    ]);
    save_csv("tab1", &t);
    t
}

/// Fig. 6: CIM array counts and utilization per model and strategy.
pub fn fig6(params: &CimParams) -> Table {
    let stats = fig6_stats(params);
    let mut t = Table::new(["model", "strategy", "arrays", "utilization", "weight MiB"]);
    for s in &stats {
        t.row([
            s.model.clone(),
            s.strategy.name().to_string(),
            s.arrays.to_string(),
            format!("{:.1}%", 100.0 * s.utilization),
            format!("{:.1}", s.memory_mib),
        ]);
    }
    t.row([
        "MEAN".into(),
        "SparseMap vs Linear".into(),
        format!(
            "-{:.0}%",
            100.0 * mean_array_reduction(&stats, Strategy::SparseMap, Strategy::Linear)
        ),
        format!(
            "{:.1}%",
            100.0 * mean_utilization(&stats, Strategy::SparseMap)
        ),
        String::new(),
    ]);
    t.row([
        "MEAN".into(),
        "DenseMap vs Linear".into(),
        format!(
            "-{:.0}%",
            100.0 * mean_array_reduction(&stats, Strategy::DenseMap, Strategy::Linear)
        ),
        format!(
            "{:.1}%",
            100.0 * mean_utilization(&stats, Strategy::DenseMap)
        ),
        String::new(),
    ]);
    save_csv("fig6", &t);
    t
}

/// Fig. 7: latency and energy across configurations (incl. GPU bar).
pub fn fig7(params: &CimParams, gpu: &GpuParams) -> Table {
    let mut t = Table::new([
        "model",
        "config",
        "latency",
        "energy",
        "speedup vs Linear",
        "energy gain vs Linear",
    ]);
    let mut sp_lat = Vec::new();
    let mut de_lat = Vec::new();
    let mut sp_en = Vec::new();
    let mut de_en = Vec::new();
    for cfg in ModelConfig::paper_models() {
        let g = gpu_cost(&cfg, gpu);
        let lin = cost_report(&cfg, params, Strategy::Linear);
        let sp = cost_report(&cfg, params, Strategy::SparseMap);
        let de = cost_report(&cfg, params, Strategy::DenseMap);
        t.row([
            cfg.name.to_string(),
            "GPU (3090 Ti)".into(),
            eng_time_ns(g.total_ns),
            eng_energy_nj(g.total_nj),
            format!(
                "{:.2}x slower",
                g.total_ns / (lin.latency_ms() * 1e6)
            ),
            format!(
                "{:.0}x more",
                g.total_nj / (lin.energy_mj() * 1e6)
            ),
        ]);
        for r in [&lin, &sp, &de] {
            t.row([
                cfg.name.to_string(),
                r.strategy.name().to_string(),
                eng_time_ns(r.latency_ms() * 1e6),
                eng_energy_nj(r.energy_mj() * 1e6),
                ratio(lin.latency_ms() / r.latency_ms()),
                ratio(lin.energy_mj() / r.energy_mj()),
            ]);
        }
        sp_lat.push(lin.latency_ms() / sp.latency_ms());
        de_lat.push(lin.latency_ms() / de.latency_ms());
        sp_en.push(lin.energy_mj() / sp.energy_mj());
        de_en.push(lin.energy_mj() / de.energy_mj());
    }
    t.row([
        "GEOMEAN".into(),
        "SparseMap".into(),
        String::new(),
        String::new(),
        ratio(geomean(&sp_lat)),
        ratio(geomean(&sp_en)),
    ]);
    t.row([
        "GEOMEAN".into(),
        "DenseMap".into(),
        String::new(),
        String::new(),
        ratio(geomean(&de_lat)),
        ratio(geomean(&de_en)),
    ]);
    save_csv("fig7", &t);
    t
}

/// Fig. 8: BERT latency/energy across ADC-sharing degrees.
pub fn fig8(adc_counts: &[usize]) -> Table {
    let cfg = ModelConfig::bert_large();
    let mut t = Table::new([
        "ADCs/array",
        "Linear lat",
        "SparseMap lat",
        "DenseMap lat",
        "Linear en",
        "SparseMap en",
        "DenseMap en",
        "best",
    ]);
    for &adcs in adc_counts {
        let p = CimParams::default().with_adcs_per_array(adcs);
        let lin = cost_report(&cfg, &p, Strategy::Linear);
        let sp = cost_report(&cfg, &p, Strategy::SparseMap);
        let de = cost_report(&cfg, &p, Strategy::DenseMap);
        let best = [
            ("Linear", lin.latency_ms()),
            ("SparseMap", sp.latency_ms()),
            ("DenseMap", de.latency_ms()),
        ]
        .into_iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
        .0;
        t.row([
            adcs.to_string(),
            format!("{:.3} ms", lin.latency_ms()),
            format!("{:.3} ms", sp.latency_ms()),
            format!("{:.3} ms", de.latency_ms()),
            format!("{:.2} mJ", lin.energy_mj()),
            format!("{:.2} mJ", sp.energy_mj()),
            format!("{:.2} mJ", de.energy_mj()),
            best.to_string(),
        ]);
    }
    save_csv("fig8", &t);
    t
}

/// §IV-C ADC resolution sweep: latency/energy vs bits (8b -> 3b = 2.67x).
pub fn adc_resolution(params: &CimParams) -> Table {
    let mut t = Table::new([
        "bits",
        "t/conv (ns)",
        "e/conv (nJ)",
        "vs 8b",
        "area proxy",
    ]);
    let t8 = adc::t_conversion_ns(params, 8);
    for bits in (3..=8).rev() {
        let c = adc::cost(params, bits);
        t.row([
            bits.to_string(),
            format!("{:.4}", c.t_ns),
            format!("{:.5}", c.e_nj),
            ratio(t8 / c.t_ns),
            format!("{:.0}", adc::area_proxy(bits)),
        ]);
    }
    save_csv("adc_resolution", &t);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_figures_render() {
        let p = CimParams::default();
        assert!(fig2b().render().contains("bert-large"));
        assert!(tab1(&p).render().contains("MVM"));
        assert!(fig6(&p).render().contains("DenseMap"));
        assert!(fig8(&[4, 8]).render().contains("ADCs"));
        assert!(adc_resolution(&p).render().contains("2.67x"));
    }

    #[test]
    fn fig7_includes_gpu_and_geomean() {
        let r = fig7(&CimParams::default(), &GpuParams::default()).render();
        assert!(r.contains("GPU (3090 Ti)"));
        assert!(r.contains("GEOMEAN"));
    }
}
