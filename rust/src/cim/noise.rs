//! PCM device non-idealities: programming noise and conductance drift.
//!
//! The paper's substrate is IBM-PCM analog CIM; real PCM cells exhibit
//! (a) write noise — the programmed conductance deviates from target by
//! a roughly Gaussian error, and (b) temporal drift — conductance decays
//! as `g(t) = g(t0) * (t/t0)^-nu` with `nu ~ 0.05` (Joshi et al., Nature
//! Comm. 2020). This module injects both into the functional crossbar so
//! the accuracy impact of analog execution on Monarch inference can be
//! quantified (failure-injection tests + ablation).

use super::crossbar::Crossbar;
use crate::util::rng::Pcg32;

/// Non-ideality parameters.
#[derive(Clone, Debug)]
pub struct PcmNoise {
    /// Std-dev of programming error, relative to the max programmed |g|.
    pub write_sigma: f64,
    /// Drift exponent nu (0 disables drift).
    pub drift_nu: f64,
    /// Read time / programming time ratio `t / t0` for drift evaluation.
    pub drift_time_ratio: f64,
}

impl Default for PcmNoise {
    fn default() -> Self {
        Self {
            write_sigma: 0.01,
            drift_nu: 0.05,
            drift_time_ratio: 1.0, // read immediately after programming
        }
    }
}

impl PcmNoise {
    /// Ideal (noise-free) configuration.
    pub fn ideal() -> Self {
        Self {
            write_sigma: 0.0,
            drift_nu: 0.0,
            drift_time_ratio: 1.0,
        }
    }

    /// Multiplicative drift factor applied to every cell.
    pub fn drift_factor(&self) -> f64 {
        if self.drift_nu == 0.0 || self.drift_time_ratio <= 0.0 {
            1.0
        } else {
            self.drift_time_ratio.powf(-self.drift_nu)
        }
    }
}

/// Opt-in analog realism for `FunctionalChip` replay (DESIGN.md §6i):
/// programming-time cell corruption plus a replay-time ADC resolution
/// cap.
///
/// Ideal settings (`write_sigma == 0`, inert drift, `adc_bits == None`)
/// are bit-identical to the exact path **by construction**: corruption
/// is skipped entirely (not applied with zero amplitude) and no
/// quantization call happens, so the replay executes byte-for-byte the
/// same instructions as a chip programmed without analog mode.
#[derive(Clone, Debug)]
pub struct AnalogMode {
    /// PCM write noise + drift applied to every programmed crossbar.
    pub noise: PcmNoise,
    /// SAR ADC resolution cap; `None` means exact conversion (a SAR
    /// converter at `bits >= adc::required_bits` resolves every
    /// distinguishable bitline level, so the digital value is exact).
    pub adc_bits: Option<u32>,
    /// Root seed: array `i` corrupts from `Pcg32::stream(seed, i)`, so
    /// the corrupted chip is a pure function of (weights, mapping,
    /// seed) — independent of programming order, identical across
    /// workers and shard stages programming the same arrays.
    pub seed: u64,
}

impl Default for AnalogMode {
    fn default() -> Self {
        Self::ideal()
    }
}

impl AnalogMode {
    /// Noise-free, full-resolution configuration.
    pub fn ideal() -> Self {
        Self {
            noise: PcmNoise::ideal(),
            adc_bits: None,
            seed: 0,
        }
    }

    /// Whether programming should corrupt cells at all. Gated so ideal
    /// settings never touch a cell (bit-identity by construction rather
    /// than relying on `x + 0.0 * err == x` holding bitwise).
    pub fn corrupts(&self) -> bool {
        self.noise.write_sigma > 0.0 || self.noise.drift_factor() != 1.0
    }
}

/// Apply programming noise + drift to a programmed crossbar in place.
pub fn corrupt(xb: &mut Crossbar, noise: &PcmNoise, rng: &mut Pcg32) {
    let gmax = xb
        .cells
        .iter()
        .fold(0.0f32, |m, &v| m.max(v.abs()))
        .max(1e-12);
    let drift = noise.drift_factor() as f32;
    for c in xb.cells.iter_mut() {
        if *c == 0.0 {
            continue; // unprogrammed cells stay at zero conductance
        }
        let err = rng.normal() * noise.write_sigma as f32 * gmax;
        *c = (*c + err) * drift;
    }
}

/// Relative output error of a noisy MVM pass vs the ideal one.
pub fn mvm_noise_error(
    xb_ideal: &Crossbar,
    noise: &PcmNoise,
    input: &[f32],
    active_rows: &[usize],
    seed: u64,
) -> f64 {
    let mut noisy = xb_ideal.clone();
    let mut rng = Pcg32::new(seed);
    corrupt(&mut noisy, noise, &mut rng);
    let want = xb_ideal.mvm_pass(input, active_rows);
    let got = noisy.mvm_pass(input, active_rows);
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (g, w) in got.iter().zip(&want) {
        num += ((g - w) as f64).powi(2);
        den += (*w as f64).powi(2);
    }
    (num / den.max(1e-30)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn programmed(seed: u64) -> Crossbar {
        let mut rng = Pcg32::new(seed);
        let mut xb = Crossbar::new(32);
        xb.program_block(0, 0, &Matrix::randn(32, 32, &mut rng));
        xb
    }

    #[test]
    fn ideal_noise_is_identity() {
        let xb = programmed(1);
        let mut noisy = xb.clone();
        let mut rng = Pcg32::new(2);
        corrupt(&mut noisy, &PcmNoise::ideal(), &mut rng);
        assert_eq!(xb.cells, noisy.cells);
    }

    #[test]
    fn error_scales_with_sigma() {
        let xb = programmed(3);
        let mut rng = Pcg32::new(4);
        let input = rng.normal_vec(32);
        let rows: Vec<usize> = (0..32).collect();
        let mut prev = 0.0;
        for sigma in [0.005, 0.02, 0.08] {
            let noise = PcmNoise {
                write_sigma: sigma,
                drift_nu: 0.0,
                drift_time_ratio: 1.0,
            };
            let err = mvm_noise_error(&xb, &noise, &input, &rows, 99);
            assert!(err > prev, "error not increasing: {err} after {prev}");
            prev = err;
        }
        assert!(prev < 0.5, "even 8% write noise keeps rel err bounded");
    }

    #[test]
    fn drift_shrinks_outputs_uniformly() {
        let noise = PcmNoise {
            write_sigma: 0.0,
            drift_nu: 0.05,
            drift_time_ratio: 1.0e6, // ~1 s -> ~11.5 days in t/t0
        };
        let factor = noise.drift_factor();
        assert!(factor < 1.0 && factor > 0.4);
        let xb = programmed(5);
        let mut noisy = xb.clone();
        let mut rng = Pcg32::new(6);
        corrupt(&mut noisy, &noise, &mut rng);
        for (n, i) in noisy.cells.iter().zip(&xb.cells) {
            assert!((n - i * factor as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_cells_stay_zero() {
        // padding cells in SparseMap layouts must not acquire conductance
        let mut xb = Crossbar::new(8);
        let mut rng = Pcg32::new(7);
        xb.program_block(0, 0, &Matrix::randn(4, 4, &mut rng));
        let mut noisy = xb.clone();
        corrupt(&mut noisy, &PcmNoise::default(), &mut rng);
        for r in 4..8 {
            for c in 4..8 {
                assert_eq!(noisy.get(r, c), 0.0);
            }
        }
    }

    #[test]
    fn all_zero_crossbar_is_a_noop() {
        // gmax degenerates to the 1e-12 guard on a never-programmed
        // array; every cell takes the zero-conductance skip.
        let mut xb = Crossbar::new(16);
        let mut rng = Pcg32::new(11);
        let noise = PcmNoise {
            write_sigma: 0.5,
            drift_nu: 0.1,
            drift_time_ratio: 10.0,
        };
        corrupt(&mut xb, &noise, &mut rng);
        assert!(xb.cells.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn sigma_zero_leaves_cells_bitwise_untouched() {
        // write_sigma = 0 with inert drift must not rewrite a single
        // bit even though corrupt still walks every programmed cell.
        let xb = programmed(12);
        let mut noisy = xb.clone();
        let mut rng = Pcg32::new(13);
        let noise = PcmNoise {
            write_sigma: 0.0,
            drift_nu: 0.0,
            drift_time_ratio: 1.0,
        };
        corrupt(&mut noisy, &noise, &mut rng);
        for (a, b) in noisy.cells.iter().zip(&xb.cells) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn drift_factor_gates() {
        let mut n = PcmNoise::ideal();
        assert_eq!(n.drift_factor(), 1.0);
        n.drift_nu = 0.05;
        n.drift_time_ratio = 0.0; // degenerate ratio disables drift
        assert_eq!(n.drift_factor(), 1.0);
        n.drift_time_ratio = 1.0e4;
        assert!(n.drift_factor() < 1.0);
    }

    #[test]
    fn analog_mode_gating() {
        assert!(!AnalogMode::ideal().corrupts());
        // drift at t/t0 = 1 is inert: factor 1.0, no corruption pass
        let at_t0 = AnalogMode {
            noise: PcmNoise {
                write_sigma: 0.0,
                drift_nu: 0.05,
                drift_time_ratio: 1.0,
            },
            ..AnalogMode::ideal()
        };
        assert!(!at_t0.corrupts());
        let noisy = AnalogMode {
            noise: PcmNoise::default(),
            ..AnalogMode::ideal()
        };
        assert!(noisy.corrupts());
    }

    #[test]
    fn monarch_inference_survives_default_noise() {
        // end-to-end: DenseMap functional chip with PCM noise still
        // approximates the Monarch operator.
        use crate::cim::CimParams;
        use crate::mapping::Strategy;
        use crate::monarch::MonarchMatrix;
        use crate::sim::exec::{single_op, FunctionalChip};
        let (cfg, ops) = single_op(64);
        let mut params = CimParams::default();
        params.array_dim = 32;
        let mut rng = Pcg32::new(8);
        let mon = MonarchMatrix::randn(8, &mut rng);
        let mut chip = FunctionalChip::program(
            &cfg,
            &ops,
            std::slice::from_ref(&mon),
            &params,
            Strategy::DenseMap,
        );
        for xb in chip.crossbars.iter_mut() {
            corrupt(xb, &PcmNoise::default(), &mut rng);
        }
        let x = rng.normal_vec(64);
        let got = chip.run_op(0, &x);
        let want = mon.matvec(&x);
        let rel = {
            let mut n = 0.0f64;
            let mut d = 0.0f64;
            for (g, w) in got.iter().zip(&want) {
                n += ((g - w) as f64).powi(2);
                d += (*w as f64).powi(2);
            }
            (n / d).sqrt()
        };
        assert!(rel < 0.1, "noisy DenseMap inference rel err {rel}");
    }
}
