//! SAR ADC model (§II-A, §IV-C): resolution-dependent latency/energy and
//! the required-resolution rule driven by row activation.
//!
//! SAR converters resolve one bit per comparison step, so latency and
//! energy scale linearly with resolution — this is exactly the paper's
//! observed `8b -> 3b = 2.67x` (= 8/3) reduction. Area grows roughly
//! with 2^bits (capacitive DAC); we report it only as a proxy metric,
//! like the paper (§VI).

use super::params::CimParams;

/// Per-conversion SAR ADC costs at a given resolution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdcCost {
    pub bits: u32,
    pub t_ns: f64,
    pub e_nj: f64,
}

/// Latency of one conversion at `bits` resolution.
pub fn t_conversion_ns(p: &CimParams, bits: u32) -> f64 {
    p.t_adc_ref_ns * bits as f64 / p.adc_ref_bits as f64
}

/// Energy of one conversion at `bits` resolution.
pub fn e_conversion_nj(p: &CimParams, bits: u32) -> f64 {
    p.e_adc_ref_nj * bits as f64 / p.adc_ref_bits as f64
}

/// Relative area proxy of one ADC at `bits` resolution (cap-DAC scaling).
pub fn area_proxy(bits: u32) -> f64 {
    (1u64 << bits) as f64
}

pub fn cost(p: &CimParams, bits: u32) -> AdcCost {
    AdcCost {
        bits,
        t_ns: t_conversion_ns(p, bits),
        e_nj: e_conversion_nj(p, bits),
    }
}

/// Worst-case resolution needed to distinguish the accumulated bitline
/// levels of `active_rows` simultaneously-driven cells (bit-serial
/// inputs): `ceil(log2(rows + 1))`, clamped to `[1, ref_bits]`.
///
/// This yields the paper's Linear = 8 b (256 rows) and SparseMap = 5 b
/// (32 rows, one block per column). DenseMap operates at 3 b — below the
/// 32-row worst case — following the paper's §IV-B operating point
/// (value-range/clipping analysis rather than the worst-case bound); the
/// quantization impact is validated numerically by the L1
/// `block_diag_mm_adc` kernel tests.
pub fn required_bits(p: &CimParams, active_rows: usize) -> u32 {
    let ceil_log2 = if active_rows <= 2 {
        1
    } else {
        usize::BITS - (active_rows - 1).leading_zeros()
    };
    ceil_log2.clamp(1, p.adc_ref_bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_scaling_matches_paper_ratio() {
        let p = CimParams::default();
        let lat8 = t_conversion_ns(&p, 8);
        let lat3 = t_conversion_ns(&p, 3);
        assert!(((lat8 / lat3) - 8.0 / 3.0).abs() < 1e-9); // 2.67x (§IV-C)
        let e8 = e_conversion_nj(&p, 8);
        let e3 = e_conversion_nj(&p, 3);
        assert!(((e8 / e3) - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn reference_point_reproduced() {
        let p = CimParams::default();
        let c = cost(&p, 8);
        assert!((c.t_ns - 0.833).abs() < 1e-9);
        assert!((c.e_nj - 13.33e-3).abs() < 1e-9);
    }

    #[test]
    fn required_bits_paper_triples() {
        let p = CimParams::default();
        assert_eq!(required_bits(&p, 256), 8); // Linear
        assert_eq!(required_bits(&p, 32), 5); // SparseMap
        assert_eq!(required_bits(&p, 8), 3); // DenseMap row-group bound
    }

    #[test]
    fn required_bits_edges() {
        let p = CimParams::default();
        assert_eq!(required_bits(&p, 1), 1);
        assert_eq!(required_bits(&p, 2), 1);
        assert_eq!(required_bits(&p, 3), 2);
        assert_eq!(required_bits(&p, 1024), 8); // clamped to ref bits
    }

    #[test]
    fn required_bits_zero_rows_and_powers_of_two() {
        let p = CimParams::default();
        // degenerate activation still needs one comparison step
        assert_eq!(required_bits(&p, 0), 1);
        // ceil(log2(rows + 1)) via rows - 1: exact powers of two need
        // exactly log2(rows) bits, one past them rounds up
        assert_eq!(required_bits(&p, 4), 2);
        assert_eq!(required_bits(&p, 5), 3);
        assert_eq!(required_bits(&p, 16), 4);
        assert_eq!(required_bits(&p, 17), 5);
        assert_eq!(required_bits(&p, 64), 6);
    }

    #[test]
    fn required_bits_clamps_to_ref_bits_range() {
        let mut p = CimParams::default();
        p.adc_ref_bits = 4;
        assert_eq!(required_bits(&p, 256), 4); // upper clamp tracks ref
        assert_eq!(required_bits(&p, 1), 1); // lower clamp
    }

    #[test]
    fn area_proxy_monotone() {
        assert!(area_proxy(8) > area_proxy(5));
        assert_eq!(area_proxy(3), 8.0);
    }
}
