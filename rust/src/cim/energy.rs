//! Latency/energy accounting structures shared by the scheduler, the
//! simulator and the report generators.

use std::ops::{Add, AddAssign};

/// Per-component latency breakdown (nanoseconds). Components follow the
/// simulator of [22]: analog array passes, ADC conversions, inter-tile
//  communication, digital (DPU) ops and the MHA unit.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Latency {
    pub analog_ns: f64,
    pub adc_ns: f64,
    pub comm_ns: f64,
    pub dpu_ns: f64,
    pub mha_ns: f64,
}

impl Latency {
    /// Sum of every component (diagnostic; over-counts overlapped work).
    pub fn total_ns(&self) -> f64 {
        self.analog_ns + self.adc_ns + self.comm_ns + self.dpu_ns + self.mha_ns
    }

    /// Critical-path latency: the analog/ADC stream dominates; shift-add,
    /// communication and DPU work pipeline behind it (their energy still
    /// counts — see `Energy`). This is the quantity Fig. 7/8 plot for the
    /// parameterized-matmul path.
    pub fn critical_ns(&self) -> f64 {
        self.analog_ns + self.adc_ns + self.mha_ns
    }
}

impl Add for Latency {
    type Output = Latency;

    fn add(self, o: Latency) -> Latency {
        Latency {
            analog_ns: self.analog_ns + o.analog_ns,
            adc_ns: self.adc_ns + o.adc_ns,
            comm_ns: self.comm_ns + o.comm_ns,
            dpu_ns: self.dpu_ns + o.dpu_ns,
            mha_ns: self.mha_ns + o.mha_ns,
        }
    }
}

impl AddAssign for Latency {
    fn add_assign(&mut self, o: Latency) {
        *self = *self + o;
    }
}

/// Per-component energy breakdown (nanojoules).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Energy {
    pub analog_nj: f64,
    pub adc_nj: f64,
    pub comm_nj: f64,
    pub dpu_nj: f64,
    pub mha_nj: f64,
}

impl Energy {
    pub fn total_nj(&self) -> f64 {
        self.analog_nj + self.adc_nj + self.comm_nj + self.dpu_nj + self.mha_nj
    }
}

impl Add for Energy {
    type Output = Energy;

    fn add(self, o: Energy) -> Energy {
        Energy {
            analog_nj: self.analog_nj + o.analog_nj,
            adc_nj: self.adc_nj + o.adc_nj,
            comm_nj: self.comm_nj + o.comm_nj,
            dpu_nj: self.dpu_nj + o.dpu_nj,
            mha_nj: self.mha_nj + o.mha_nj,
        }
    }
}

impl AddAssign for Energy {
    fn add_assign(&mut self, o: Energy) {
        *self = *self + o;
    }
}

/// Combined cost of an execution fragment.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    pub latency: Latency,
    pub energy: Energy,
}

impl Add for Cost {
    type Output = Cost;

    fn add(self, o: Cost) -> Cost {
        Cost {
            latency: self.latency + o.latency,
            energy: self.energy + o.energy,
        }
    }
}

impl AddAssign for Cost {
    fn add_assign(&mut self, o: Cost) {
        *self = *self + o;
    }
}

impl Cost {
    /// Merge a fragment that runs *in parallel* with this one: energies
    /// add, latency takes the max (by critical path) per the slot model.
    pub fn parallel_merge(&mut self, o: &Cost) {
        self.energy += o.energy;
        if o.latency.critical_ns() > self.latency.critical_ns() {
            self.latency = o.latency;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_sum_components() {
        let l = Latency {
            analog_ns: 1.0,
            adc_ns: 2.0,
            comm_ns: 3.0,
            dpu_ns: 4.0,
            mha_ns: 5.0,
        };
        assert_eq!(l.total_ns(), 15.0);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut c = Cost::default();
        c += Cost {
            latency: Latency {
                adc_ns: 10.0,
                ..Default::default()
            },
            energy: Energy {
                adc_nj: 1.0,
                ..Default::default()
            },
        };
        c += c;
        assert_eq!(c.latency.adc_ns, 20.0);
        assert_eq!(c.energy.adc_nj, 2.0);
    }

    #[test]
    fn parallel_merge_takes_max_latency_sum_energy() {
        let mut a = Cost {
            latency: Latency {
                adc_ns: 10.0,
                ..Default::default()
            },
            energy: Energy {
                adc_nj: 5.0,
                ..Default::default()
            },
        };
        let b = Cost {
            latency: Latency {
                adc_ns: 30.0,
                ..Default::default()
            },
            energy: Energy {
                adc_nj: 7.0,
                ..Default::default()
            },
        };
        a.parallel_merge(&b);
        assert_eq!(a.latency.adc_ns, 30.0);
        assert_eq!(a.energy.adc_nj, 12.0);
    }
}
