//! Analog CIM accelerator substrate: Table-I cost parameters, the SAR
//! ADC model, the functional crossbar, and cost-accounting types.
//!
//! This is our from-scratch equivalent of the AIMC simulator the paper
//! uses ([22]); see DESIGN.md §1 for the substitution rationale and §5
//! for the timing-model interpretation.

pub mod adc;
pub mod bitblocks;
pub mod crossbar;
pub mod noise;
pub mod energy;
pub mod params;

pub use bitblocks::BitBlocks;
pub use crossbar::Crossbar;
pub use energy::{Cost, Energy, Latency};
pub use noise::{AnalogMode, PcmNoise};
pub use params::CimParams;
