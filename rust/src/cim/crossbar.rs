//! Functional crossbar array model: programmed cells, selective row
//! activation, analog MVM emulation with optional ADC quantization.
//!
//! This is the *numerics* half of the CIM substrate (the cost half lives
//! in `scheduler::timing`). The mapping strategies program weights into
//! `Crossbar`s; the functional simulator (`sim::exec`) drives inputs
//! through them with the scheduler's row-activation masks and checks the
//! results against the dense reference — the paper's "naively activating
//! all rows would produce incorrect results" failure mode is an explicit
//! negative test.

use crate::cim::bitblocks::BitBlocks;
use crate::tensor::Matrix;

/// One m x m analog crossbar with programmed conductances.
#[derive(Clone, Debug)]
pub struct Crossbar {
    pub dim: usize,
    /// Row-major cell values; `cells[r * dim + c]`.
    pub cells: Vec<f32>,
}

impl Crossbar {
    pub fn new(dim: usize) -> Self {
        Self {
            dim,
            cells: vec![0.0; dim * dim],
        }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.cells[r * self.dim + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.cells[r * self.dim + c] = v;
    }

    /// Program a dense block at `(r0, c0)` (array write; counted by the
    /// scheduler as a write op).
    pub fn program_block(&mut self, r0: usize, c0: usize, block: &Matrix) {
        assert!(
            r0 + block.rows <= self.dim && c0 + block.cols <= self.dim,
            "block exceeds array bounds"
        );
        for r in 0..block.rows {
            let dst = (r0 + r) * self.dim + c0;
            self.cells[dst..dst + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// Analog MVM pass: drive `input[r]` on each row `r` in `active_rows`,
    /// read accumulated bitline currents on all columns.
    /// `y[c] = sum_{r in active} input[r] * cells[r][c]`.
    pub fn mvm_pass(&self, input: &[f32], active_rows: &[usize]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.dim];
        self.mvm_pass_into(input, active_rows, &mut y);
        y
    }

    /// Allocation-free form of [`Crossbar::mvm_pass`]: accumulate into a
    /// caller-owned full-width buffer (every element is overwritten).
    pub fn mvm_pass_into(&self, input: &[f32], active_rows: &[usize], out: &mut [f32]) {
        assert_eq!(input.len(), self.dim, "input must span all rows");
        assert_eq!(out.len(), self.dim, "output must span all columns");
        out.fill(0.0);
        for &r in active_rows {
            let xv = input[r];
            if xv == 0.0 {
                continue;
            }
            let row = &self.cells[r * self.dim..(r + 1) * self.dim];
            for (acc, w) in out.iter_mut().zip(row) {
                *acc += xv * w;
            }
        }
    }

    /// Column-restricted analog pass: convert ONLY the listed columns —
    /// `out[k] = sum_{r in active} input[r] * cells[r][cols[k]]`.
    ///
    /// This is the sparsity-aware inner loop of the compiled-plan replay
    /// (`scheduler::plan`): O(active_rows × cols) work instead of
    /// O(active_rows × m), an m/b reduction for DenseMap block walks.
    /// Accumulation order per column is identical to [`Crossbar::mvm_pass`]
    /// (rows in `active_rows` order, zero inputs skipped), so each
    /// converted column is bit-identical to the full pass.
    pub fn mvm_pass_cols(
        &self,
        input: &[f32],
        active_rows: &[usize],
        cols: &[usize],
        out: &mut [f32],
    ) {
        assert_eq!(input.len(), self.dim, "input must span all rows");
        assert_eq!(out.len(), cols.len(), "one output per converted column");
        out.fill(0.0);
        for &r in active_rows {
            let xv = input[r];
            if xv == 0.0 {
                continue;
            }
            let row = &self.cells[r * self.dim..(r + 1) * self.dim];
            for (acc, &c) in out.iter_mut().zip(cols) {
                *acc += xv * row[c];
            }
        }
    }

    /// Batched column-restricted pass: convert the listed columns for
    /// `batch` stacked input vectors in one analog pass. Lanes are
    /// stride-`batch` interleaved — `input[r * batch + l]` is lane `l`'s
    /// voltage on row `r`, `out[k * batch + l]` is lane `l`'s conversion
    /// of column `cols[k]`.
    ///
    /// Once the weights are resident this is how serving amortizes the
    /// pass: the same driven-rows/conversion-cols schedule converts a
    /// column-*block* of activations instead of one vector. Lanes can be
    /// concurrent *sequences* (batched decode, `B` slots) or concurrent
    /// *positions* of one prompt (chunked prefill, `sim::prefill` —
    /// prefill positions are mutually independent through every Para
    /// matmul). Per lane the accumulation order is identical to
    /// [`Crossbar::mvm_pass_cols`] (rows in `active_rows` order, zero
    /// inputs skipped), so every lane is bit-identical to a B=1 pass
    /// over that lane's vector.
    pub fn mvm_batch_cols(
        &self,
        input: &[f32],
        batch: usize,
        active_rows: &[usize],
        cols: &[usize],
        out: &mut [f32],
    ) {
        assert!(batch > 0, "batch must be positive");
        assert_eq!(input.len(), self.dim * batch, "input must span rows x batch");
        assert_eq!(out.len(), cols.len() * batch, "one output per column per lane");
        out.fill(0.0);
        for &r in active_rows {
            let lanes = &input[r * batch..(r + 1) * batch];
            let row = &self.cells[r * self.dim..(r + 1) * self.dim];
            for (k, &c) in cols.iter().enumerate() {
                let w = row[c];
                for (acc, &xv) in out[k * batch..(k + 1) * batch].iter_mut().zip(lanes) {
                    if xv != 0.0 {
                        *acc += xv * w;
                    }
                }
            }
        }
    }

    /// Bit-block form of [`Crossbar::mvm_pass_cols`] (ISSUE 6 tentpole):
    /// the driven rows and scheduled columns arrive as [`BitBlocks`] and
    /// the kernel walks their set-bit **runs** — each run's columns are
    /// a contiguous cell span zipped against a contiguous output span,
    /// so the inner loop has no per-index gather and no bounds checks. A
    /// fully-set column block degenerates to one whole-width zip (the
    /// identity fast path).
    ///
    /// Rows are visited in ascending order with the same zero-input
    /// skip, and f32 accumulation per column is unchanged — so for the
    /// ascending index lists the planner emits this is **bit-identical**
    /// to `mvm_pass_cols` (property-tested in `tests/prop_exec_plan.rs`).
    pub fn mvm_pass_bits(
        &self,
        input: &[f32],
        rows: &BitBlocks,
        cols: &BitBlocks,
        out: &mut [f32],
    ) {
        assert_eq!(input.len(), self.dim, "input must span all rows");
        assert_eq!(out.len(), cols.len(), "one output per converted column");
        out.fill(0.0);
        for (r0, _, rlen) in rows.runs() {
            for r in r0..r0 + rlen {
                let xv = input[r];
                if xv == 0.0 {
                    continue;
                }
                let row = &self.cells[r * self.dim..(r + 1) * self.dim];
                for (c0, k0, clen) in cols.runs() {
                    for (acc, w) in
                        out[k0..k0 + clen].iter_mut().zip(&row[c0..c0 + clen])
                    {
                        *acc += xv * w;
                    }
                }
            }
        }
    }

    /// Bit-block form of [`Crossbar::mvm_batch_cols`]: stride-`batch`
    /// interleaved lanes accumulated over column *runs* — each cell read
    /// updates `batch` adjacent accumulators, and consecutive columns of
    /// a run land in consecutive lane groups of `out`, so the kernel
    /// touches memory strictly forward with no per-index bounds checks.
    /// Per lane, row order and the zero-input skip match
    /// [`Crossbar::mvm_batch_cols`] exactly (bit-identical outputs).
    pub fn mvm_batch_bits(
        &self,
        input: &[f32],
        batch: usize,
        rows: &BitBlocks,
        cols: &BitBlocks,
        out: &mut [f32],
    ) {
        assert!(batch > 0, "batch must be positive");
        assert_eq!(input.len(), self.dim * batch, "input must span rows x batch");
        assert_eq!(out.len(), cols.len() * batch, "one output per column per lane");
        out.fill(0.0);
        for (r0, _, rlen) in rows.runs() {
            for r in r0..r0 + rlen {
                let lanes = &input[r * batch..(r + 1) * batch];
                let row = &self.cells[r * self.dim..(r + 1) * self.dim];
                for (c0, k0, clen) in cols.runs() {
                    let seg = &mut out[k0 * batch..(k0 + clen) * batch];
                    for (k, &w) in row[c0..c0 + clen].iter().enumerate() {
                        for (acc, &xv) in
                            seg[k * batch..(k + 1) * batch].iter_mut().zip(lanes)
                        {
                            if xv != 0.0 {
                                *acc += xv * w;
                            }
                        }
                    }
                }
            }
        }
    }

    /// MVM pass followed by SAR ADC readout quantization (mid-tread,
    /// `bits` resolution over ±`full_scale`). Mirrors the L1 kernel
    /// `block_diag_mm_adc` / `ref.adc_quantize`. Quantizes in place —
    /// no second buffer behind the pass itself.
    pub fn mvm_pass_quantized(
        &self,
        input: &[f32],
        active_rows: &[usize],
        bits: u32,
        full_scale: f32,
    ) -> Vec<f32> {
        let mut y = self.mvm_pass(input, active_rows);
        for v in y.iter_mut() {
            *v = quantize(*v, bits, full_scale);
        }
        y
    }

    /// Fraction of cells holding non-zero weights (utilization).
    pub fn utilization(&self) -> f64 {
        let nz = self.cells.iter().filter(|v| **v != 0.0).count();
        nz as f64 / self.cells.len() as f64
    }
}

/// Mid-tread uniform quantizer used for the ADC readout emulation.
pub fn quantize(v: f32, bits: u32, full_scale: f32) -> f32 {
    let levels = ((1u64 << bits) - 1) as f32;
    let step = 2.0 * full_scale / levels;
    let half = (levels as i64 / 2) as f32;
    (v / step).round().clamp(-half, half) * step
}

/// Quantize a converted-column slice in place — the replay-path form of
/// [`quantize`] (one shared full-scale per analog pass; the caller
/// derives it from the array's programmed conductance range).
pub fn quantize_slice(buf: &mut [f32], bits: u32, full_scale: f32) {
    for v in buf.iter_mut() {
        *v = quantize(*v, bits, full_scale);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn mvm_matches_dense_with_all_rows() {
        let mut rng = Pcg32::new(1);
        let w = Matrix::randn(8, 8, &mut rng);
        let mut xb = Crossbar::new(8);
        xb.program_block(0, 0, &w);
        let x = rng.normal_vec(8);
        let all: Vec<usize> = (0..8).collect();
        let got = xb.mvm_pass(&x, &all);
        // y[c] = sum_r x[r] W[r, c] = (W^T x)[c]
        let want = w.transpose().matvec(&x);
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 1e-4);
        }
    }

    #[test]
    fn selective_rows_isolate_blocks() {
        // Two blocks packed in the same columns (DenseMap-style overlap):
        // activating the wrong row set corrupts results, the right set
        // isolates the block. This is §III-C's correctness argument.
        let mut xb = Crossbar::new(4);
        let b0 = Matrix::from_vec(2, 4, vec![1.0; 8]);
        let b1 = Matrix::from_vec(2, 4, vec![10.0; 8]);
        xb.program_block(0, 0, &b0);
        xb.program_block(2, 0, &b1);
        let x = vec![1.0; 4];
        let only_b0 = xb.mvm_pass(&x, &[0, 1]);
        assert_eq!(only_b0, vec![2.0; 4]);
        let all = xb.mvm_pass(&x, &[0, 1, 2, 3]);
        assert_eq!(all, vec![22.0; 4]); // mixed — incorrect for either block
    }

    #[test]
    fn quantize_is_monotone_and_bounded() {
        for bits in [3u32, 5, 8] {
            let fs = 4.0;
            let mut prev = f32::NEG_INFINITY;
            for i in -100..=100 {
                let v = i as f32 * 0.1;
                let q = quantize(v, bits, fs);
                assert!(q >= prev - 1e-6);
                assert!(q.abs() <= fs + 1e-6);
                prev = q;
            }
        }
    }

    #[test]
    fn quantized_pass_error_shrinks_with_bits() {
        let mut rng = Pcg32::new(2);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let x = rng.normal_vec(16);
        let all: Vec<usize> = (0..16).collect();
        let exact = xb.mvm_pass(&x, &all);
        let mut errs = Vec::new();
        for bits in [3u32, 5, 8] {
            let q = xb.mvm_pass_quantized(&x, &all, bits, 16.0);
            let err: f32 = exact
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b).abs())
                .sum::<f32>();
            errs.push(err);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2]);
    }

    #[test]
    fn mvm_pass_cols_bit_identical_to_full_pass() {
        // Any column subset, in any order, must reproduce the full pass's
        // values exactly (same accumulation order per column) — the
        // contract the compiled-plan replay relies on.
        let mut rng = Pcg32::new(3);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let mut x = rng.normal_vec(16);
        x[3] = 0.0; // exercise the zero-input skip on both paths
        let active: Vec<usize> = vec![0, 3, 5, 6, 9, 15];
        let full = xb.mvm_pass(&x, &active);
        for cols in [vec![0usize, 1, 2], vec![15, 2, 7], (0..16).collect()] {
            let mut out = vec![f32::NAN; cols.len()];
            xb.mvm_pass_cols(&x, &active, &cols, &mut out);
            for (k, &c) in cols.iter().enumerate() {
                assert_eq!(out[k].to_bits(), full[c].to_bits(), "col {c}");
            }
        }
    }

    #[test]
    fn mvm_batch_cols_bit_identical_per_lane() {
        // Each lane of a batched pass must equal the single-vector
        // column-restricted pass over that lane, bit for bit — the
        // contract the batched replay (and batched decode) rests on.
        let mut rng = Pcg32::new(4);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let active: Vec<usize> = vec![1, 4, 7, 8, 12];
        let cols: Vec<usize> = vec![3, 0, 11, 15];
        for batch in [1usize, 2, 3, 8] {
            let lanes: Vec<Vec<f32>> = (0..batch)
                .map(|l| {
                    let mut x = rng.normal_vec(16);
                    x[4] = if l % 2 == 0 { 0.0 } else { x[4] }; // zero-skip path
                    x
                })
                .collect();
            let mut xi = vec![0.0f32; 16 * batch];
            for (l, x) in lanes.iter().enumerate() {
                for (r, &v) in x.iter().enumerate() {
                    xi[r * batch + l] = v;
                }
            }
            let mut out = vec![f32::NAN; cols.len() * batch];
            xb.mvm_batch_cols(&xi, batch, &active, &cols, &mut out);
            for (l, x) in lanes.iter().enumerate() {
                let mut want = vec![0.0f32; cols.len()];
                xb.mvm_pass_cols(x, &active, &cols, &mut want);
                for k in 0..cols.len() {
                    assert_eq!(
                        out[k * batch + l].to_bits(),
                        want[k].to_bits(),
                        "batch {batch} lane {l} col {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn mvm_batch_cols_handles_prefill_width_lane_counts() {
        // Chunked prefill drives lane counts well past the decode slot
        // pool (lanes = prompt positions, e.g. 16 or 33 per pass); the
        // per-lane bit-identity contract must hold at those widths too.
        let mut rng = Pcg32::new(6);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let active: Vec<usize> = vec![0, 2, 5, 9, 14];
        let cols: Vec<usize> = vec![1, 6, 13];
        for batch in [16usize, 33] {
            let lanes: Vec<Vec<f32>> = (0..batch).map(|_| rng.normal_vec(16)).collect();
            let mut xi = vec![0.0f32; 16 * batch];
            for (l, x) in lanes.iter().enumerate() {
                for (r, &v) in x.iter().enumerate() {
                    xi[r * batch + l] = v;
                }
            }
            let mut out = vec![f32::NAN; cols.len() * batch];
            xb.mvm_batch_cols(&xi, batch, &active, &cols, &mut out);
            for (l, x) in lanes.iter().enumerate() {
                let mut want = vec![0.0f32; cols.len()];
                xb.mvm_pass_cols(x, &active, &cols, &mut want);
                for k in 0..cols.len() {
                    assert_eq!(
                        out[k * batch + l].to_bits(),
                        want[k].to_bits(),
                        "batch {batch} lane {l} col {k}"
                    );
                }
            }
        }
    }

    #[test]
    fn mvm_pass_bits_bit_identical_to_index_lists() {
        // the bit-block kernel must reproduce the index-list kernel
        // exactly on the ascending row/col sets the planner emits,
        // including gapped runs and the fully-dense identity set
        let mut rng = Pcg32::new(5);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let mut x = rng.normal_vec(16);
        x[5] = 0.0; // exercise the zero-input skip on both paths
        let row_sets: Vec<Vec<usize>> =
            vec![vec![0, 1, 2, 5, 6, 7, 15], (0..16).collect(), vec![8]];
        let col_sets: Vec<Vec<usize>> =
            vec![vec![0, 1, 2, 3], vec![4, 5, 10, 11, 12], (0..16).collect()];
        for active in &row_sets {
            for cols in &col_sets {
                let rb = BitBlocks::from_sorted(active, 16);
                let cb = BitBlocks::from_sorted(cols, 16);
                let mut want = vec![0.0f32; cols.len()];
                xb.mvm_pass_cols(&x, active, cols, &mut want);
                let mut got = vec![f32::NAN; cols.len()];
                xb.mvm_pass_bits(&x, &rb, &cb, &mut got);
                for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "col slot {k}");
                }
            }
        }
    }

    #[test]
    fn mvm_batch_bits_bit_identical_per_lane() {
        let mut rng = Pcg32::new(8);
        let w = Matrix::randn(16, 16, &mut rng);
        let mut xb = Crossbar::new(16);
        xb.program_block(0, 0, &w);
        let active: Vec<usize> = vec![0, 1, 4, 5, 6, 12, 13];
        let cols: Vec<usize> = vec![2, 3, 4, 9, 15];
        let rb = BitBlocks::from_sorted(&active, 16);
        let cb = BitBlocks::from_sorted(&cols, 16);
        for batch in [1usize, 2, 3, 8, 17] {
            let lanes: Vec<Vec<f32>> = (0..batch)
                .map(|l| {
                    let mut x = rng.normal_vec(16);
                    x[4] = if l % 2 == 0 { 0.0 } else { x[4] }; // zero-skip
                    x
                })
                .collect();
            let mut xi = vec![0.0f32; 16 * batch];
            for (l, x) in lanes.iter().enumerate() {
                for (r, &v) in x.iter().enumerate() {
                    xi[r * batch + l] = v;
                }
            }
            let mut want = vec![0.0f32; cols.len() * batch];
            xb.mvm_batch_cols(&xi, batch, &active, &cols, &mut want);
            let mut got = vec![f32::NAN; cols.len() * batch];
            xb.mvm_batch_bits(&xi, batch, &rb, &cb, &mut got);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.to_bits(), w.to_bits(), "batch {batch} slot {k}");
            }
        }
    }

    #[test]
    fn utilization_counts_programmed_cells() {
        let mut xb = Crossbar::new(4);
        assert_eq!(xb.utilization(), 0.0);
        xb.program_block(0, 0, &Matrix::from_vec(2, 2, vec![1.0; 4]));
        assert!((xb.utilization() - 4.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_program_rejected() {
        let mut xb = Crossbar::new(4);
        xb.program_block(3, 3, &Matrix::from_vec(2, 2, vec![1.0; 4]));
    }
}
