//! CIM hardware cost parameters — paper Table I (IBM-PCM-class analog
//! CIM at d_model = 1024) plus the architectural knobs the DSE sweeps
//! (§IV-C): ADCs per array and per-strategy ADC resolution.
//!
//! Interpretation notes (see DESIGN.md §5):
//! * `MVM (256x256 PCM) = 100 ns / 10 nJ` is the cost of one full-array
//!   analog pass: DAC input streaming + bitline settle (latency), and the
//!   array conduction energy at full row/column activation (energy). The
//!   energy of a pass with partial activation scales with the active-row
//!   fraction.
//! * ADC costs are per conversion at 8 b; SAR conversion latency *and*
//!   energy scale linearly with resolution (the paper's own 8b->3b =
//!   2.67x claim), area scales ~2^bits (reported as a proxy only).
//! * Communication is per inter-tile vector transfer (48 ns / 51.7 nJ).
//! * DPU costs are per token-vector op at d_model = 1024.

/// Static cost/config parameters of the simulated CIM accelerator.
#[derive(Clone, Debug)]
pub struct CimParams {
    /// Crossbar dimension (rows = cols = m).
    pub array_dim: usize,
    /// ADCs attached to each array (shared across columns via mux).
    pub adcs_per_array: usize,
    /// Input (DAC) bit-streaming width per analog pass.
    pub input_bits: u32,

    // --- analog array (Table I row 1) ---
    /// Full-array analog MVM pass latency (ns): DAC streaming + settle.
    pub t_mvm_ns: f64,
    /// Fraction of `e_mvm_nj` that is cell conduction + DAC drive; the
    /// remainder is the reference ADC bank, which the scheduler accounts
    /// explicitly per conversion (excluded here to avoid double
    /// counting). Cf. [14]: converters are 60-80% of CIM MVM energy.
    pub analog_fraction: f64,
    /// Full-array analog MVM pass energy (nJ) at 100% row activation.
    pub e_mvm_nj: f64,

    // --- SAR ADC (Table I row 2, 8 b reference point) ---
    pub adc_ref_bits: u32,
    pub t_adc_ref_ns: f64,
    pub e_adc_ref_nj: f64,

    // --- communication (Table I row 3) ---
    pub t_comm_ns: f64,
    pub e_comm_nj: f64,

    // --- digital processing units (Table I rows 4-5), per token vector ---
    pub t_layernorm_ns: f64,
    pub e_layernorm_nj: f64,
    pub t_relu_ns: f64,
    pub e_relu_nj: f64,
    pub t_gelu_ns: f64,
    pub e_gelu_nj: f64,
    pub t_add_ns: f64,
    pub e_add_nj: f64,
    /// Peripheral shift-add energy per partial-sum combine (nJ) —
    /// array-adjacent adders, cheaper than a full DPU vector add
    /// (Accelergy-style estimate).
    pub e_shift_add_nj: f64,
}

impl Default for CimParams {
    /// Table I values verbatim.
    fn default() -> Self {
        Self {
            array_dim: 256,
            adcs_per_array: 1, // Fig. 7 operating point (§IV-B)
            input_bits: 8,
            t_mvm_ns: 100.0,
            analog_fraction: 0.3,
            e_mvm_nj: 10.0,
            adc_ref_bits: 8,
            t_adc_ref_ns: 0.833,
            e_adc_ref_nj: 13.33e-3,
            t_comm_ns: 48.0,
            e_comm_nj: 51.7,
            t_layernorm_ns: 100.0,
            e_layernorm_nj: 42.0,
            t_relu_ns: 1.0,
            e_relu_nj: 0.06,
            t_gelu_ns: 70.0,
            e_gelu_nj: 38.5,
            t_add_ns: 36.0,
            e_add_nj: 37.7,
            e_shift_add_nj: 15.0,
        }
    }
}

impl CimParams {
    /// DSE variant with a given ADC-sharing degree (Fig. 8 x-axis).
    pub fn with_adcs_per_array(mut self, adcs: usize) -> Self {
        assert!(adcs >= 1, "need at least one ADC per array");
        self.adcs_per_array = adcs;
        self
    }

    /// Cells per array.
    pub fn array_cells(&self) -> usize {
        self.array_dim * self.array_dim
    }

    /// Per-token analog drive latency of one pass (ns) when conversions
    /// are modelled separately. The Table-I 100 ns covers a full pass
    /// including the reference ADC bank; bit-serial DAC streaming
    /// overlaps with column sampling 4:1, leaving `input_bits / 4`
    /// cycles = 2 ns of exposed drive time per pass.
    pub fn t_drive_ns(&self) -> f64 {
        self.input_bits as f64 / 4.0
    }

    /// Analog pass energy at a given active-row fraction (the ADC share
    /// of the Table-I composite is accounted separately per conversion).
    pub fn e_pass_nj(&self, active_row_frac: f64) -> f64 {
        self.e_mvm_nj * self.analog_fraction * active_row_frac.clamp(0.0, 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let p = CimParams::default();
        assert_eq!(p.array_dim, 256);
        assert_eq!(p.array_cells(), 65536);
        assert!((p.t_adc_ref_ns - 0.833).abs() < 1e-12);
        assert!((p.e_adc_ref_nj - 13.33e-3).abs() < 1e-12);
        assert!((p.t_gelu_ns - 70.0).abs() < 1e-12);
    }

    #[test]
    fn dse_variant() {
        let p = CimParams::default().with_adcs_per_array(16);
        assert_eq!(p.adcs_per_array, 16);
    }

    #[test]
    fn pass_energy_scales_with_activation() {
        let p = CimParams::default();
        assert!((p.e_pass_nj(1.0) - 3.0).abs() < 1e-12);
        assert!((p.e_pass_nj(0.5) - 1.5).abs() < 1e-12);
        assert_eq!(p.e_pass_nj(2.0), 3.0); // clamped
    }
}
