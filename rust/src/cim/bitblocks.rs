//! Bit-block encoding of the compiled pass tables' index sets (ISSUE 6
//! tentpole; DESIGN.md §6e).
//!
//! A compiled pass names its driven rows and scheduled columns. PR 2
//! stored those as `Vec<usize>` index lists — the naive sparse encoding
//! whose per-index loads and bounds checks dominate the replay inner
//! loop. [`BitBlocks`] re-encodes a sorted index set as u64 words (one
//! word per 64 array rows/columns) plus a per-word **dense-offset
//! prefix sum**, giving two O(1) primitives the replay builds on:
//!
//! * **popcnt sparse→dense indexing** ([`BitBlocks::rank`]): the dense
//!   position of sparse index `i` is
//!   `offsets[i/64] + (words[i/64] & !(u64::MAX << i%64)).count_ones()`
//!   — the count of set bits strictly before `i`. A fully-set block
//!   degenerates to the identity (`rank(i) == i` when the set is
//!   `0..len`), which [`BitBlocks::is_identity`] exposes so consumers
//!   can skip translation entirely.
//! * **run iteration** ([`BitBlocks::runs`]): maximal runs of
//!   consecutive set bits, merged across word boundaries, yielded as
//!   `(sparse_start, dense_start, len)` triples. Every run maps a
//!   contiguous dense range onto a contiguous sparse range, so the
//!   replay stages inputs with `copy_from_slice` and accumulates
//!   columns with contiguous slice zips — no per-index bounds checks
//!   ([`crate::cim::crossbar::Crossbar::mvm_pass_bits`]).
//!
//! The encoding is exact for every pass the planner emits (all three
//! strategies produce strictly ascending row/column lists —
//! `scheduler::plan`), and the word-boundary cases (sets ending at bit
//! 63/64/65, runs spanning words) are pinned by the unit tests below
//! and by `tests/prop_exec_plan.rs` at array dims 63/64/65.

/// A sorted set of indices over a fixed universe `0..bits`, stored as
/// u64 bit-block words with per-word dense-offset prefix sums.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitBlocks {
    /// `words[w]` holds membership of indices `64w..64w+64` (bit `i%64`
    /// of word `i/64` is set iff `i` is in the set).
    words: Vec<u64>,
    /// `offsets[w]` = number of set bits in `words[..w]` — the dense
    /// offset at which word `w`'s members start.
    offsets: Vec<u32>,
    /// Number of set bits (dense length).
    len: usize,
    /// Universe size the words span.
    bits: usize,
    /// The set is exactly `0..len` — rank is the identity.
    identity: bool,
}

impl BitBlocks {
    /// Encode a strictly ascending index list over universe `0..bits`.
    pub fn from_sorted(indices: &[usize], bits: usize) -> BitBlocks {
        for w in indices.windows(2) {
            assert!(w[0] < w[1], "indices must be strictly ascending");
        }
        if let Some(&last) = indices.last() {
            assert!(last < bits, "index {last} outside universe 0..{bits}");
        }
        let mut words = vec![0u64; bits.div_ceil(64)];
        for &i in indices {
            words[i / 64] |= 1u64 << (i % 64);
        }
        let mut offsets = Vec::with_capacity(words.len());
        let mut acc = 0u32;
        for &w in &words {
            offsets.push(acc);
            acc += w.count_ones();
        }
        let identity = match indices.last() {
            Some(&last) => last + 1 == indices.len(),
            None => true,
        };
        BitBlocks {
            words,
            offsets,
            len: indices.len(),
            bits,
            identity,
        }
    }

    /// Number of set bits (the dense length).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Universe size.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Raw bit-block words.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// The set is exactly `0..len()`: every rank equals its index and
    /// consumers may bypass sparse→dense translation (the fully-set
    /// block fast path — all words below the boundary are `u64::MAX`).
    pub fn is_identity(&self) -> bool {
        self.identity
    }

    pub fn contains(&self, i: usize) -> bool {
        i < self.bits && (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Dense index of sparse index `i` (which must be a member): the
    /// popcount of members strictly before `i`, via the per-word prefix
    /// sum plus an in-word masked popcnt. `i % 64 < 64` always, so the
    /// mask shift never overflows; a fully-set word degenerates to
    /// `offsets[w] + i % 64` (identity within the word).
    #[inline]
    pub fn rank(&self, i: usize) -> usize {
        debug_assert!(self.contains(i), "rank of non-member {i}");
        let (w, b) = (i / 64, i % 64);
        self.offsets[w] as usize
            + (self.words[w] & !(u64::MAX << b)).count_ones() as usize
    }

    /// Iterate members in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut cur = w;
            std::iter::from_fn(move || {
                if cur == 0 {
                    return None;
                }
                let tz = cur.trailing_zeros() as usize;
                cur &= cur - 1; // clear lowest set bit
                Some(wi * 64 + tz)
            })
        })
    }

    /// Reconstruct the sorted index list (tests / diagnostics).
    pub fn indices(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// Iterate maximal runs of consecutive members — merged across word
    /// boundaries — as `(sparse_start, dense_start, len)`. Allocation
    /// free; the replay hot loop's unit of work.
    pub fn runs(&self) -> Runs<'_> {
        Runs {
            words: &self.words,
            word: 0,
            cur: self.words.first().copied().unwrap_or(0),
            dense: 0,
        }
    }
}

/// Iterator state of [`BitBlocks::runs`].
pub struct Runs<'a> {
    words: &'a [u64],
    /// Current word index.
    word: usize,
    /// Unconsumed bits of the current word.
    cur: u64,
    /// Dense index of the next yielded member.
    dense: usize,
}

impl Iterator for Runs<'_> {
    type Item = (usize, usize, usize);

    fn next(&mut self) -> Option<(usize, usize, usize)> {
        while self.cur == 0 {
            self.word += 1;
            if self.word >= self.words.len() {
                return None;
            }
            self.cur = self.words[self.word];
        }
        let tz = self.cur.trailing_zeros() as usize;
        let start = self.word * 64 + tz;
        let run = (self.cur >> tz).trailing_ones() as usize;
        let mut len = run;
        if tz + run == 64 {
            // the run reaches the top of the word: it may continue into
            // following words (which must then be set from bit 0 up)
            self.cur = 0;
            while self.word + 1 < self.words.len() {
                let nxt = self.words[self.word + 1];
                let t1 = nxt.trailing_ones() as usize;
                if t1 == 0 {
                    break;
                }
                self.word += 1;
                len += t1;
                if t1 == 64 {
                    self.cur = 0;
                } else {
                    // consume the continuation bits, keep the rest
                    self.cur = nxt & (u64::MAX << t1);
                    break;
                }
            }
        } else {
            // consume the run's bits (shift < 64 here)
            self.cur &= u64::MAX << (tz + run);
        }
        let dense = self.dense;
        self.dense += len;
        Some((start, dense, len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference rank: position in the sorted list.
    fn rank_by_scan(indices: &[usize], i: usize) -> usize {
        indices.iter().position(|&x| x == i).unwrap()
    }

    /// Expand runs back into the index list they cover.
    fn expand_runs(bb: &BitBlocks) -> Vec<usize> {
        let mut out = Vec::new();
        let mut expect_dense = 0usize;
        for (s, d, l) in bb.runs() {
            assert_eq!(d, expect_dense, "dense offsets must be cumulative");
            expect_dense += l;
            out.extend(s..s + l);
        }
        out
    }

    #[test]
    fn rank_matches_linear_scan() {
        let cases: Vec<(Vec<usize>, usize)> = vec![
            (vec![0, 1, 2, 3], 8),
            (vec![3, 7, 8, 9, 63, 64, 65, 127], 130),
            ((0..64).collect(), 64),
            ((0..65).collect(), 65),
            (vec![62, 63], 64),
            (vec![], 10),
        ];
        for (indices, bits) in cases {
            let bb = BitBlocks::from_sorted(&indices, bits);
            assert_eq!(bb.len(), indices.len());
            assert_eq!(bb.bits(), bits);
            for &i in &indices {
                assert!(bb.contains(i));
                assert_eq!(bb.rank(i), rank_by_scan(&indices, i), "rank({i})");
            }
        }
    }

    #[test]
    fn rank_formula_is_the_documented_popcnt_expression() {
        // the SNIPPETS bit-block mapping: dense index of bit `i` within
        // one word is (block & !(u64::MAX << i)).count_ones()
        let indices: Vec<usize> = vec![1, 4, 5, 30, 63];
        let bb = BitBlocks::from_sorted(&indices, 64);
        let block = bb.words()[0];
        for &i in &indices {
            let dense = (block & !(u64::MAX << i)).count_ones() as usize;
            assert_eq!(bb.rank(i), dense);
        }
    }

    #[test]
    fn word_boundary_sets_63_64_65() {
        // the geometries ISSUE 6 calls out: sets ending exactly below,
        // at, and above the first u64 boundary
        for n in [63usize, 64, 65] {
            let indices: Vec<usize> = (0..n).collect();
            let bb = BitBlocks::from_sorted(&indices, n);
            assert!(bb.is_identity(), "0..{n} is the identity");
            assert_eq!(bb.indices(), indices);
            assert_eq!(expand_runs(&bb), indices, "runs must merge at n={n}");
            assert_eq!(bb.runs().count(), 1, "one merged run at n={n}");
            for &i in &indices {
                assert_eq!(bb.rank(i), i);
            }
        }
    }

    #[test]
    fn runs_merge_across_word_boundaries() {
        // a run straddling bit 63/64, with separate runs on both sides
        let indices: Vec<usize> = vec![5, 6, 62, 63, 64, 65, 100];
        let bb = BitBlocks::from_sorted(&indices, 128);
        let runs: Vec<(usize, usize, usize)> = bb.runs().collect();
        assert_eq!(runs, vec![(5, 0, 2), (62, 2, 4), (100, 6, 1)]);
        assert_eq!(expand_runs(&bb), indices);
        assert!(!bb.is_identity());
    }

    #[test]
    fn runs_span_multiple_full_words() {
        // 130 consecutive members crossing two word boundaries collapse
        // into ONE run (full middle word)
        let indices: Vec<usize> = (10..140).collect();
        let bb = BitBlocks::from_sorted(&indices, 160);
        assert_eq!(bb.runs().collect::<Vec<_>>(), vec![(10, 0, 130)]);
        for &i in &indices {
            assert_eq!(bb.rank(i), i - 10);
        }
    }

    #[test]
    fn identity_detection() {
        assert!(BitBlocks::from_sorted(&[], 0).is_identity());
        assert!(BitBlocks::from_sorted(&[0], 7).is_identity());
        assert!(BitBlocks::from_sorted(&(0..32).collect::<Vec<_>>(), 64).is_identity());
        // offset or gapped sets are not the identity
        assert!(!BitBlocks::from_sorted(&[1], 7).is_identity());
        assert!(!BitBlocks::from_sorted(&[0, 2], 7).is_identity());
    }

    #[test]
    fn empty_set_has_no_runs() {
        let bb = BitBlocks::from_sorted(&[], 100);
        assert!(bb.is_empty());
        assert_eq!(bb.runs().count(), 0);
        assert_eq!(bb.iter().count(), 0);
        assert!(!bb.contains(3));
    }

    #[test]
    fn iter_matches_indices_on_scattered_sets() {
        let indices: Vec<usize> = vec![0, 2, 3, 64, 66, 127, 128, 191];
        let bb = BitBlocks::from_sorted(&indices, 192);
        assert_eq!(bb.iter().collect::<Vec<_>>(), indices);
        assert_eq!(expand_runs(&bb), indices);
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn unsorted_input_rejected() {
        BitBlocks::from_sorted(&[3, 2], 8);
    }

    #[test]
    #[should_panic(expected = "outside universe")]
    fn out_of_universe_rejected() {
        BitBlocks::from_sorted(&[8], 8);
    }
}
