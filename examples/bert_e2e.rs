//! End-to-end driver — the repo's headline experiment.
//!
//! ```bash
//! make artifacts && cargo run --release --example bert_e2e
//! ```
//!
//! Exercises every layer of the stack on a real small workload:
//!
//! * **Framework** (L3): run the full D2S -> map -> schedule -> simulate
//!   pipeline for BERT-large / BART-large / GPT-2-medium under all three
//!   mapping strategies and print the paper's headline numbers (Fig. 6/7).
//! * **Numeric D2S** (L3 + L1): project a synthetic near-Monarch
//!   1024x1024 weight in Rust, feed the factors to the AOT-compiled
//!   Pallas kernel (`monarch_mvm_n1024`) via PJRT, and verify the result
//!   against both the Rust reference and the original dense operator.
//! * **Serving** (L3 + L2 + L1): start the batching inference server over
//!   the `tiny_lm` Monarch transformer artifacts and push batched token
//!   workloads through it, reporting latency/throughput.
//!
//! Results of a reference run are recorded in EXPERIMENTS.md.

use monarch_cim::coordinator::batching::BatchPolicy;
use monarch_cim::coordinator::{run_pipeline, InferenceServer, PipelineConfig, ServerConfig};
use monarch_cim::gpu::{gpu_cost, GpuParams};
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::monarch::{monarch_project, MonarchMatrix};
use monarch_cim::runtime::{literal_f32, literals_from_monarch, Runtime};
use monarch_cim::tensor::Matrix;
use monarch_cim::util::rng::Pcg32;
use monarch_cim::util::stats::geomean;

fn main() {
    phase1_framework();
    phase2_d2s_through_pjrt();
    phase3_serving();
    println!("\nbert_e2e OK — record these numbers in EXPERIMENTS.md");
}

/// Phase 1: the paper's evaluation across models and strategies.
fn phase1_framework() {
    println!("== phase 1: framework pipeline (Fig. 6 / Fig. 7) ==");
    let gpu = GpuParams::default();
    let mut sp_lat = Vec::new();
    let mut de_lat = Vec::new();
    let mut sp_en = Vec::new();
    let mut de_en = Vec::new();
    for model in ModelConfig::paper_models() {
        let g = gpu_cost(&model, &gpu);
        let mut lin_ms = 0.0;
        for strategy in Strategy::all() {
            let r = run_pipeline(&PipelineConfig::new(model.clone(), strategy));
            if strategy == Strategy::Linear {
                lin_ms = r.cost.latency_ms();
                println!(
                    "  {:<12} GPU        latency {:>9.2} ms  (CIM Linear is {:.1}x faster)",
                    model.name,
                    g.total_ns / 1e6,
                    g.total_ns / 1e6 / lin_ms
                );
            }
            println!(
                "  {:<12} {:<9} arrays {:>5}  util {:>5.1}%  lat {:>8.3} ms  en {:>7.2} mJ",
                model.name,
                strategy.name(),
                r.mapping.arrays,
                100.0 * r.mapping.utilization(),
                r.cost.latency_ms(),
                r.cost.energy_mj()
            );
            match strategy {
                Strategy::SparseMap => {
                    sp_lat.push(lin_ms / r.cost.latency_ms());
                    sp_en.push(
                        run_pipeline(&PipelineConfig::new(model.clone(), Strategy::Linear))
                            .cost
                            .energy_mj()
                            / r.cost.energy_mj(),
                    );
                }
                Strategy::DenseMap => {
                    de_lat.push(lin_ms / r.cost.latency_ms());
                    de_en.push(
                        run_pipeline(&PipelineConfig::new(model.clone(), Strategy::Linear))
                            .cost
                            .energy_mj()
                            / r.cost.energy_mj(),
                    );
                }
                Strategy::Linear => {}
            }
        }
    }
    println!(
        "  GEOMEAN latency speedup vs Linear: SparseMap {:.2}x (paper 1.59x), DenseMap {:.2}x (paper 1.73x)",
        geomean(&sp_lat),
        geomean(&de_lat)
    );
    println!(
        "  GEOMEAN energy gain   vs Linear: SparseMap {:.2}x (paper 1.61x), DenseMap {:.2}x (paper 1.74x)",
        geomean(&sp_en),
        geomean(&de_en)
    );
}

/// Phase 2: Rust D2S factors through the AOT Pallas kernel at BERT scale.
fn phase2_d2s_through_pjrt() {
    println!("\n== phase 2: D2S -> PJRT round trip (n = 1024, b = 32) ==");
    let mut rt = match Runtime::with_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            println!("  SKIPPED: {e}");
            return;
        }
    };
    let mut rng = Pcg32::new(20);
    let d = 1024;
    let b = 32;
    let base = MonarchMatrix::randn(b, &mut rng)
        .to_dense()
        .scale(1.0 / b as f32);
    let w = base.add(&Matrix::randn(d, d, &mut rng).scale(0.005));
    let t0 = std::time::Instant::now();
    let m = monarch_project(&w);
    let proj_time = t0.elapsed();
    let x = Matrix::randn(4, d, &mut rng);
    let (l, r) = literals_from_monarch(&m).unwrap();
    let t1 = std::time::Instant::now();
    let got = rt
        .execute_f32(
            "monarch_mvm_n1024",
            &[l, r, literal_f32(&x.data, &[4, d]).unwrap()],
        )
        .expect("PJRT execution");
    let exec_time = t1.elapsed();
    let got_m = Matrix::from_vec(4, d, got);
    let want_rust = m.matmul_rows(&x);
    let want_dense = x.matmul(&w.transpose());
    println!(
        "  D2S projection: {proj_time:?}; PJRT exec (incl. compile): {exec_time:?}"
    );
    println!(
        "  kernel vs Rust-reference rel err: {:.2e}",
        got_m.rel_error(&want_rust)
    );
    println!(
        "  Monarch vs original dense rel err: {:.4} (projection quality)",
        got_m.rel_error(&want_dense)
    );
    assert!(got_m.rel_error(&want_rust) < 1e-3);
}

/// Phase 3: batched serving workload over the Monarch tiny-LM artifacts.
fn phase3_serving() {
    println!("\n== phase 3: batched serving (tiny Monarch LM over PJRT) ==");
    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch: 8,
            max_delay: std::time::Duration::from_millis(2),
        },
        ..Default::default()
    };
    let server = match InferenceServer::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            println!("  SKIPPED: {e}");
            return;
        }
    };
    let n_requests = 256;
    let seq = server.seq;
    let vocab = server.vocab as u32;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for i in 0..n_requests {
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Pcg32::new(i as u64);
                let toks: Vec<i32> = (0..seq).map(|_| rng.below(vocab) as i32).collect();
                let logits = srv.infer(toks).expect("inference");
                assert_eq!(logits.len(), seq * srv.vocab);
            });
        }
    });
    let elapsed = t0.elapsed();
    let s = server.metrics.snapshot();
    println!(
        "  {} requests in {:.2?} -> {:.1} req/s ({:.1} tok/s)",
        s.requests,
        elapsed,
        s.requests as f64 / elapsed.as_secs_f64(),
        (s.requests as usize * seq) as f64 / elapsed.as_secs_f64()
    );
    println!(
        "  batches {}, mean batch {:.2}, latency p50 {:.2} ms, p99 {:.2} ms, errors {}",
        s.batches,
        s.mean_batch,
        s.latency_p50_us / 1e3,
        s.latency_p99_us / 1e3,
        s.errors
    );
    server.shutdown();
}
