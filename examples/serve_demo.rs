//! Serving demo: sustained batched inference against the Monarch tiny-LM
//! artifacts with live metrics — the L3 request loop in isolation.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_demo -- --requests 512 --clients 16
//! ```

use monarch_cim::coordinator::batching::BatchPolicy;
use monarch_cim::coordinator::{InferenceServer, ServerConfig};
use monarch_cim::util::cli::Args;
use monarch_cim::util::rng::Pcg32;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let total = args.usize_or("requests", 512);
    let clients = args.usize_or("clients", 16);
    let max_batch = args.usize_or("max-batch", 8);
    let max_delay_ms = args.usize_or("max-delay-ms", 2);

    let cfg = ServerConfig {
        policy: BatchPolicy {
            max_batch,
            max_delay: std::time::Duration::from_millis(max_delay_ms as u64),
        },
        ..Default::default()
    };
    println!(
        "starting server: max_batch={max_batch}, linger={max_delay_ms}ms, {clients} clients, {total} requests"
    );
    let server = match InferenceServer::start(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server failed to start: {e:#} (run `make artifacts`)");
            std::process::exit(1);
        }
    };

    let seq = server.seq;
    let vocab = server.vocab as u32;
    let per_client = total / clients;
    let t0 = std::time::Instant::now();
    std::thread::scope(|scope| {
        for c in 0..clients {
            let srv = &server;
            scope.spawn(move || {
                let mut rng = Pcg32::stream(2026, c as u64);
                for _ in 0..per_client {
                    let toks: Vec<i32> =
                        (0..seq).map(|_| rng.below(vocab) as i32).collect();
                    // greedy next-token readout from the last position
                    let logits = srv.infer(toks).expect("inference");
                    let last = &logits[(seq - 1) * srv.vocab..];
                    let argmax = last
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    std::hint::black_box(argmax);
                }
            });
        }
    });
    let elapsed = t0.elapsed();
    let s = server.metrics.snapshot();
    println!(
        "done: {} requests in {:.2?}\n  throughput: {:.1} req/s ({:.0} tok/s)\n  \
         batching: {} batches, mean size {:.2}\n  \
         latency: p50 {:.2} ms, p99 {:.2} ms\n  errors: {}",
        s.requests,
        elapsed,
        s.requests as f64 / elapsed.as_secs_f64(),
        (s.requests as usize * seq) as f64 / elapsed.as_secs_f64(),
        s.batches,
        s.mean_batch,
        s.latency_p50_us / 1e3,
        s.latency_p99_us / 1e3,
        s.errors
    );
    server.shutdown();
}
