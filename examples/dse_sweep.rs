//! Design-space exploration (paper §IV-C, Fig. 8): sweep ADC sharing
//! degree and ADC resolution, print the crossover analysis.
//!
//! ```bash
//! cargo run --release --example dse_sweep -- --adcs 1,2,4,8,16,32 --model bert
//! ```

use monarch_cim::cim::{adc, CimParams};
use monarch_cim::mapping::Strategy;
use monarch_cim::model::ModelConfig;
use monarch_cim::scheduler::timing::cost_report;
use monarch_cim::util::cli::Args;
use monarch_cim::util::table::Table;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let model = ModelConfig::by_name(&args.str_or("model", "bert")).expect("model");
    let adcs = args.usize_list_or("adcs", &[1, 2, 4, 8, 16, 32]);

    println!("== Fig. 8 — ADC sharing DSE ({}) ==", model.name);
    let mut t = Table::new([
        "ADCs/array",
        "Linear (ms)",
        "SparseMap (ms)",
        "DenseMap (ms)",
        "Linear (mJ)",
        "SparseMap (mJ)",
        "DenseMap (mJ)",
        "winner",
    ]);
    let mut crossover: Option<usize> = None;
    let mut prev_winner = "";
    for &a in &adcs {
        let p = CimParams::default().with_adcs_per_array(a);
        let lin = cost_report(&model, &p, Strategy::Linear);
        let sp = cost_report(&model, &p, Strategy::SparseMap);
        let de = cost_report(&model, &p, Strategy::DenseMap);
        let winner = [
            ("DenseMap", de.latency_ms()),
            ("SparseMap", sp.latency_ms()),
            ("Linear", lin.latency_ms()),
        ]
        .into_iter()
        .min_by(|x, y| x.1.partial_cmp(&y.1).unwrap())
        .unwrap()
        .0;
        if !prev_winner.is_empty() && winner != prev_winner && crossover.is_none() {
            crossover = Some(a);
        }
        prev_winner = winner;
        t.row([
            a.to_string(),
            format!("{:.3}", lin.latency_ms()),
            format!("{:.3}", sp.latency_ms()),
            format!("{:.3}", de.latency_ms()),
            format!("{:.2}", lin.energy_mj()),
            format!("{:.2}", sp.energy_mj()),
            format!("{:.2}", de.energy_mj()),
            winner.to_string(),
        ]);
    }
    t.print();
    if let Some(c) = crossover {
        println!(
            "crossover at {c} ADCs/array — paper: DenseMap best at 4, \
             SparseMap best at 32, DenseMap flat beyond 8"
        );
    }

    println!("\n== §IV-C — ADC resolution scaling ==");
    let p = CimParams::default();
    let mut t2 = Table::new(["bits", "t/conv (ns)", "vs 8b", "area proxy"]);
    let t8 = adc::t_conversion_ns(&p, 8);
    for bits in (3..=8).rev() {
        t2.row([
            bits.to_string(),
            format!("{:.4}", adc::t_conversion_ns(&p, bits)),
            format!("{:.2}x", t8 / adc::t_conversion_ns(&p, bits)),
            format!("{:.0}", adc::area_proxy(bits)),
        ]);
    }
    t2.print();
    println!("8b -> 3b: {:.2}x (paper: 2.67x)", 8.0 / 3.0);

    // array-budget ablation (§III-B1: swap overhead on constrained
    // systems — the capacity argument for DenseMap)
    println!("\n== ablation — array-budget constraint (swap overhead) ==");
    use monarch_cim::mapping::constrained::{constrained_token_latency_ns, WriteCosts};
    let costs = WriteCosts::default();
    let p1 = CimParams::default();
    let mut t4 = Table::new([
        "array budget",
        "Linear µs/tok",
        "SparseMap µs/tok",
        "DenseMap µs/tok",
        "DenseMap speedup",
    ]);
    for budget in [usize::MAX, 4608, 2304, 1024, 512, 350] {
        let lat = |s: Strategy| {
            let mm = monarch_cim::mapping::map_model(&model, &p1, s);
            constrained_token_latency_ns(&mm, &model, &p1, budget, &costs) / 1e3
        };
        let (l, sp, de) = (
            lat(Strategy::Linear),
            lat(Strategy::SparseMap),
            lat(Strategy::DenseMap),
        );
        t4.row([
            if budget == usize::MAX {
                "unlimited".to_string()
            } else {
                budget.to_string()
            },
            format!("{l:.1}"),
            format!("{sp:.1}"),
            format!("{de:.1}"),
            format!("{:.1}x", l / de),
        ]);
    }
    t4.print();

    // block-size ablation (§IV-A residual utilization claim)
    println!("\n== ablation — DenseMap utilization vs array dim ==");
    let mut t3 = Table::new(["array dim m", "lanes (m/b)", "arrays", "utilization"]);
    for m in [64usize, 128, 256, 512] {
        let mut p = CimParams::default();
        p.array_dim = m;
        let mm = monarch_cim::mapping::map_model(&model, &p, Strategy::DenseMap);
        t3.row([
            m.to_string(),
            (m / mm.b.max(1)).to_string(),
            mm.arrays.to_string(),
            format!("{:.1}%", 100.0 * mm.utilization()),
        ]);
    }
    t3.print();
}
