//! Quickstart: the whole framework on one weight matrix, in five steps.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! 1. Take a dense 1024x1024 "pre-trained" weight (synthetic, near the
//!    Monarch class — the regime D2S fine-tuning targets).
//! 2. D2S-transform it into Monarch factors (paper §III-A).
//! 3. Map the factors onto 256x256 CIM arrays with all three strategies
//!    and compare footprint/utilization (§III-B, Fig. 6).
//! 4. Cost out an inference pass with the mapping-aware scheduler
//!    (§III-C, Fig. 7).
//! 5. Numerically validate the DenseMap schedule on emulated crossbars.

use monarch_cim::cim::CimParams;
use monarch_cim::mapping::{map_ops, Strategy};
use monarch_cim::monarch::{monarch_project, MonarchMatrix};
use monarch_cim::scheduler::timing::cost_report_for_mapping;
use monarch_cim::sim::exec::{single_op, FunctionalChip};
use monarch_cim::tensor::Matrix;
use monarch_cim::util::rng::Pcg32;

fn main() {
    let d = 1024;
    let b = 32;
    let mut rng = Pcg32::new(7);

    // 1) synthetic near-Monarch dense weight
    println!("== 1. dense weight ({d}x{d}) ==");
    let base = MonarchMatrix::randn(b, &mut rng)
        .to_dense()
        .scale(1.0 / b as f32);
    let w = base.add(&Matrix::randn(d, d, &mut rng).scale(0.01));
    println!("   ||W||_F = {:.1}", w.frobenius());

    // 2) D2S projection
    println!("== 2. D2S transformation (blockwise rank-1 SVD) ==");
    let t0 = std::time::Instant::now();
    let m = monarch_project(&w);
    let rel = m.to_dense().rel_error(&w);
    println!(
        "   projected in {:?}; rel. Frobenius error {:.4}; params {} -> {} ({}x)",
        t0.elapsed(),
        rel,
        d * d,
        m.params(),
        d * d / m.params()
    );

    // 3) mapping comparison
    println!("== 3. CIM mapping (m = 256) ==");
    let (cfg, ops) = {
        let (mut c, o) = single_op(d);
        c.d_model = d;
        (c, o)
    };
    let params = CimParams::default();
    for strategy in Strategy::all() {
        let mm = map_ops(&cfg, &ops, &params, strategy);
        println!(
            "   {:<10} arrays {:>3}  utilization {:>6.1}%",
            strategy.name(),
            mm.arrays,
            100.0 * mm.utilization()
        );
    }

    // 4) scheduled cost
    println!("== 4. scheduled inference cost (1 ADC/array) ==");
    for strategy in Strategy::all() {
        let mm = map_ops(&cfg, &ops, &params, strategy);
        let c = cost_report_for_mapping(&cfg, &mm, &params);
        println!(
            "   {:<10} {:>7.2} µs/token   {:>8.1} nJ/token   ({}b ADC)",
            strategy.name(),
            c.per_token.latency.critical_ns() / 1e3,
            c.per_token.energy.total_nj(),
            c.adc_bits
        );
    }

    // 5) functional validation of the capacity-optimized schedule
    println!("== 5. functional check (DenseMap on emulated crossbars) ==");
    let small = 64; // functional sim at b=8 for speed
    let (cfg_s, ops_s) = single_op(small);
    let mut p_small = CimParams::default();
    p_small.array_dim = 32;
    let mon = MonarchMatrix::randn(8, &mut rng);
    let chip = FunctionalChip::program(
        &cfg_s,
        &ops_s,
        std::slice::from_ref(&mon),
        &p_small,
        Strategy::DenseMap,
    );
    let x = rng.normal_vec(small);
    let got = chip.run_op(0, &x);
    let want = mon.matvec(&x);
    let err: f32 = got
        .iter()
        .zip(&want)
        .map(|(g, w)| (g - w).abs())
        .fold(0.0, f32::max);
    println!(
        "   max |scheduled - reference| = {err:.2e} over {} crossbars (util {:.0}%)",
        chip.crossbars.len(),
        100.0 * chip.measured_utilization()
    );
    assert!(err < 1e-3, "functional check failed");
    println!("quickstart OK");
}
